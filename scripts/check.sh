#!/usr/bin/env bash
# Tier-1 gate: the full pytest suite plus a kernel-bench smoke run.
# Usage: scripts/check.sh  (or `make check`)
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests =="
python -m pytest -x -q

echo
echo "== kernel bench smoke =="
python -m benchmarks.run --only kernels

#!/usr/bin/env bash
# Tier-1 gate: the full pytest suite plus a kernel-bench smoke run.
# Usage: scripts/check.sh  (or `make check`)
#   CHECK_PARITY=1 scripts/check.sh  additionally runs the selector/engine
#   parity suites as one command (`make parity`).
#   CHECK_BENCH_SMOKE=1 scripts/check.sh  additionally runs the engine
#   bench smoke and refreshes BENCH_selection.json (perf trajectory).
#   CHECK_BENCH_SHAPLEY=1 scripts/check.sh  additionally runs the dense-
#   vs-streaming Shapley bench and refreshes BENCH_shapley.json.
#   CHECK_TELEMETRY=1 scripts/check.sh  additionally runs the telemetry
#   overhead bench (off vs host-side vs live tap) and refreshes
#   BENCH_telemetry.json.
#   CHECK_CLIENT_SCALE=1 scripts/check.sh  additionally runs the client-
#   axis sharding smoke (dense vs sharded per-device bytes, DESIGN.md §16)
#   and refreshes BENCH_clients.json.
#   CHECK_PROFILE=1 scripts/check.sh  additionally runs the §17 profile
#   smoke (cost cards on every compile event + capture-window stage walls).
#   CHECK_BENCH_COMM=1 scripts/check.sh  additionally runs the §18
#   communication-efficiency Pareto grid (one partitioned run_grid over
#   strategies x codecs + the fused-codec microbench) and refreshes
#   BENCH_comm.json.
#   CHECK_FAULTS=1 scripts/check.sh  additionally runs the §19 chaos
#   smoke (fault-rate convergence curves + quarantine overhead) and
#   refreshes BENCH_faults.json.
#   CHECK_BENCH_TREND=1 scripts/check.sh  additionally diffs the current
#   BENCH_*.json against benchmarks/baselines/ and fails on regression
#   (appends to the BENCH_trajectory.json ledger either way).
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests =="
python -m pytest -x -q

echo
echo "== kernel bench smoke =="
python -m benchmarks.run --only kernels

if [[ "${CHECK_PARITY:-0}" == "1" ]]; then
  echo
  echo "== selector/engine parity =="
  make parity
fi

if [[ "${CHECK_BENCH_SMOKE:-0}" == "1" ]]; then
  echo
  echo "== engine bench smoke (BENCH_selection.json) =="
  make bench-smoke
fi

if [[ "${CHECK_BENCH_SHAPLEY:-0}" == "1" ]]; then
  echo
  echo "== shapley bench smoke (BENCH_shapley.json) =="
  make bench-shapley
fi

if [[ "${CHECK_GRID_SMOKE:-0}" == "1" ]]; then
  echo
  echo "== grid runner smoke (BENCH_grid.json) =="
  make grid-smoke
fi

if [[ "${CHECK_CLIENT_SCALE:-0}" == "1" ]]; then
  echo
  echo "== client-axis sharding smoke (BENCH_clients.json) =="
  make client-scale-smoke
fi

if [[ "${CHECK_TELEMETRY:-0}" == "1" ]]; then
  echo
  echo "== telemetry overhead smoke (BENCH_telemetry.json) =="
  make telemetry-smoke
fi

if [[ "${CHECK_PROFILE:-0}" == "1" ]]; then
  echo
  echo "== profile smoke (cost cards + capture window) =="
  make profile-smoke
fi

if [[ "${CHECK_BENCH_COMM:-0}" == "1" ]]; then
  echo
  echo "== comm-efficiency Pareto grid (BENCH_comm.json) =="
  make bench-comm
fi

if [[ "${CHECK_FAULTS:-0}" == "1" ]]; then
  echo
  echo "== fault-injection chaos smoke (BENCH_faults.json) =="
  make faults-smoke
fi

if [[ "${CHECK_BENCH_TREND:-0}" == "1" ]]; then
  echo
  echo "== bench regression gate (BENCH_* vs benchmarks/baselines) =="
  make bench-check
fi

.PHONY: check test parity bench-kernels bench-engine bench-smoke grid-smoke bench-shapley telemetry-smoke client-scale-smoke bench-comm profile-smoke faults-smoke bench-check seed-baselines

check:
	./scripts/check.sh

test:
	PYTHONPATH=src python -m pytest -x -q

# the parity contract in one command: host-vs-device selector parity plus
# loop/batched/scan engine parity (selections bit-identical, params to
# jit-fusion tolerance).  Opt into the check gate with
# CHECK_PARITY=1 ./scripts/check.sh
parity:
	PYTHONPATH=src python -m pytest -x -q tests/test_selection.py tests/test_engine.py

bench-kernels:
	PYTHONPATH=src python -m benchmarks.run --only kernels

bench-engine:
	PYTHONPATH=src python -m benchmarks.run --only engine

# small-size engine bench that refreshes BENCH_selection.json (dispatch
# counts + loop/batched/scan latencies); opt into the check gate with
# CHECK_BENCH_SMOKE=1 ./scripts/check.sh
bench-smoke:
	PYTHONPATH=src python -m benchmarks.engine_bench --smoke --json BENCH_selection.json

# dense-vs-streaming device GTG-Shapley smoke (DESIGN.md §8 vs §14):
# e2e SV latency, compiled-flops evidence of the M-fold construction
# reduction, peak-model-bytes estimates; refreshes BENCH_shapley.json.
# Opt into the check gate with CHECK_BENCH_SHAPLEY=1 ./scripts/check.sh
bench-shapley:
	PYTHONPATH=src python -m benchmarks.engine_bench --shapley --json BENCH_shapley.json

# telemetry overhead smoke (DESIGN.md §15): e2e scan runs with telemetry
# off vs host-side JSONL vs the in-scan live tap (interleaved min-of-reps)
# plus a schema-validated segmented-grid event stream; refreshes
# BENCH_telemetry.json.  The host-side stream must stay < 2% overhead.
# Opt into the check gate with CHECK_TELEMETRY=1 ./scripts/check.sh
telemetry-smoke:
	PYTHONPATH=src python -m benchmarks.engine_bench --telemetry --json BENCH_telemetry.json

# client-axis sharding smoke (DESIGN.md §16): per-device client-state
# bytes + round latency, dense vs sharded over the forced-host 8-device
# debug mesh; refreshes BENCH_clients.json (N=300 subset; drop --smoke
# for the full N in {300, 3k, 30k} sweep).  Opt into the check gate with
# CHECK_CLIENT_SCALE=1 ./scripts/check.sh
client-scale-smoke:
	XLA_FLAGS=--xla_force_host_platform_device_count=8 \
	PYTHONPATH=src python -m benchmarks.client_scale --smoke --json BENCH_clients.json

# communication-efficiency ledger (paper title claim): the strategies x
# codecs Pareto frontier as ONE partitioned run_grid call, plus the fused
# delta-codec microbench; refreshes BENCH_comm.json (gate with
# CHECK_BENCH_COMM=1 scripts/check.sh)
bench-comm:
	PYTHONPATH=src python -m benchmarks.comm_efficiency --json BENCH_comm.json

# §17 profile smoke: tiny telemetry-on scan + grid runs with the profiler
# capture window open; asserts every compile event carries a populated
# cost card and the profile event recovers per-stage walls.  Opt into the
# check gate with CHECK_PROFILE=1 ./scripts/check.sh
profile-smoke:
	PYTHONPATH=src python -m benchmarks.profile_smoke

# §19 chaos smoke: convergence-under-fault-rate curves (greedyfed vs
# random, quarantine on) plus the hardened-path overhead measurement;
# refreshes BENCH_faults.json (deterministic quarantine counts watched by
# regress.py).  Opt into the check gate with CHECK_FAULTS=1 ./scripts/check.sh
faults-smoke:
	PYTHONPATH=src python -m benchmarks.fault_bench --smoke --json BENCH_faults.json

# §17 bench-regression gate: diff the repo-root BENCH_*.json against the
# committed baselines in benchmarks/baselines/ (tolerance bands per
# metric) and append one entry to the BENCH_trajectory.json ledger; exits
# nonzero on regression.  Opt into the check gate with
# CHECK_BENCH_TREND=1 ./scripts/check.sh
bench-check:
	PYTHONPATH=src python -m repro.telemetry.regress

# re-seed benchmarks/baselines/ from the current BENCH_*.json (after an
# intentional perf change or bench-schema bump, commit the new baselines)
seed-baselines:
	PYTHONPATH=src python -m repro.telemetry.regress --seed

# grid-runner smoke: a 2-partition, 2-segment, 4-replica grid sharded over
# the forced-host 8-device debug mesh; refreshes BENCH_grid.json (per-
# partition dispatch counts, segment latency, bytes resident).  Opt into
# the check gate with CHECK_GRID_SMOKE=1 ./scripts/check.sh
grid-smoke:
	XLA_FLAGS=--xla_force_host_platform_device_count=8 \
	PYTHONPATH=src python -m benchmarks.engine_bench --grid --json BENCH_grid.json

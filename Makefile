.PHONY: check test bench-kernels bench-engine

check:
	./scripts/check.sh

test:
	PYTHONPATH=src python -m pytest -x -q

bench-kernels:
	PYTHONPATH=src python -m benchmarks.run --only kernels

bench-engine:
	PYTHONPATH=src python -m benchmarks.run --only engine

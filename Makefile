.PHONY: check test bench-kernels bench-engine bench-smoke

check:
	./scripts/check.sh

test:
	PYTHONPATH=src python -m pytest -x -q

bench-kernels:
	PYTHONPATH=src python -m benchmarks.run --only kernels

bench-engine:
	PYTHONPATH=src python -m benchmarks.run --only engine

# small-size engine bench that refreshes BENCH_selection.json (dispatch
# counts + loop/batched/scan latencies); opt into the check gate with
# CHECK_BENCH_SMOKE=1 ./scripts/check.sh
bench-smoke:
	PYTHONPATH=src python -m benchmarks.engine_bench --smoke --json BENCH_selection.json

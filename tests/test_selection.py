"""Selection strategies: RR initialisation coverage, greedy top-M, softmax
sampling validity, Power-of-Choice loss bias — plus the host-vs-device
parity contract for the pure-JAX selector stack (selection_jax)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.selection import (
    SELECTORS, SelectionContext, make_selector, selector_spec,
)
from repro.core.selection_jax import (
    DeviceSelectionContext, device_dropped_fraction, device_select,
    device_update, init_device_state, make_selector_spec, poc_d_schedule,
)


def _ctx(n, losses=None):
    return SelectionContext(
        data_fractions=jnp.ones(n) / n,
        local_losses=None if losses is None else jnp.asarray(losses))


def test_greedyfed_round_robin_covers_all_clients():
    n, m = 10, 3
    sel = make_selector("greedyfed", n, m, seed=1)
    state = sel.init_state()
    seen = set()
    rr_rounds = int(np.ceil(n / m))
    for t in range(rr_rounds):
        s, state = sel.select(state, jax.random.key(t), _ctx(n))
        seen.update(int(i) for i in s)
        state = sel.update(state, s, sv_round=jnp.zeros(m))
    assert seen == set(range(n)), "RR phase must value every client once"


def test_greedyfed_selects_top_sv_after_rr():
    n, m = 6, 2
    sel = make_selector("greedyfed", n, m, seed=0)
    state = sel.init_state()
    rr_rounds = int(np.ceil(n / m))
    for t in range(rr_rounds):
        s, state = sel.select(state, jax.random.key(t), _ctx(n))
        # hand clients k a known value == k
        state = sel.update(state, s, sv_round=jnp.asarray(
            [float(i) for i in s]))
    s, _ = sel.select(state, jax.random.key(99), _ctx(n))
    assert set(int(i) for i in s) == {n - 1, n - 2}, "greedy must pick top-M"


def test_ucb_prefers_unexplored_among_equal_values():
    n, m = 4, 1
    sel = make_selector("ucb", n, m, seed=0, c=10.0)
    state = sel.init_state()
    for t in range(4):  # RR
        s, state = sel.select(state, jax.random.key(t), _ctx(n))
        state = sel.update(state, s, sv_round=jnp.zeros(1))
    # select client 0 twice more -> its UCB bonus shrinks
    for t in range(2):
        state = sel.update(state, np.array([0]), sv_round=jnp.zeros(1))
    s, _ = sel.select(state, jax.random.key(9), _ctx(n))
    assert int(s[0]) != 0


def test_power_of_choice_picks_highest_loss():
    n, m = 8, 2
    sel = make_selector("power_of_choice", n, m, seed=0, d0=8, decay=1.0)
    state = sel.init_state()
    losses = np.arange(n, dtype=np.float32)
    s, _ = sel.select(state, jax.random.key(0), _ctx(n, losses))
    assert set(int(i) for i in s) <= set(range(n))
    assert min(int(i) for i in s) >= n - 4, "should pick from high-loss tail"


def test_sfedavg_returns_valid_distinct_clients():
    n, m = 10, 4
    sel = make_selector("s_fedavg", n, m, seed=0)
    state = sel.init_state()
    s, _ = sel.select(state, jax.random.key(0), _ctx(n))
    assert len(set(int(i) for i in s)) == m


def test_unknown_selector_raises():
    with pytest.raises(ValueError, match="options"):
        make_selector("nope", 4, 2)


def test_unknown_selector_spec_lists_options():
    """Satellite: the runtime spec factory names every valid strategy in
    its error instead of surfacing a bare KeyError."""
    from repro.core.selection_jax import strategy_names

    with pytest.raises(ValueError) as e:
        make_selector_spec("nope", 4, 2)
    for name in strategy_names():
        assert name in str(e.value)
    assert "KeyError" not in repr(e.value)
    with pytest.raises(TypeError, match="unexpected"):
        make_selector_spec("greedyfed", 4, 2, decay=0.5)


# ------------------------------------------------- device-resident parity --
_jit_select = jax.jit(device_select, static_argnums=0)
_jit_update = jax.jit(device_update, static_argnums=0)


def _drive_both(name, seed, n=9, m=3, rounds=8, **kw):
    """Run host and jitted-device selectors side by side on one synthetic
    round stream; assert bit-identical selections every round and matching
    final state."""
    host = make_selector(name, n, m, seed=seed, **kw)
    spec = selector_spec(host)
    hstate = host.init_state()
    dstate = init_device_state(spec, seed)
    d_sched = poc_d_schedule(spec, rounds)
    rng = np.random.default_rng(seed + 7)
    fractions = jnp.asarray(rng.random(n).astype(np.float32) + 0.1)
    key = jax.random.key(seed + 100)
    for t in range(rounds):
        key, sk = jax.random.split(key)
        losses = jnp.asarray(rng.standard_normal(n).astype(np.float32))
        hs, hstate = host.select(
            hstate, sk, SelectionContext(fractions, losses))
        ds, dstate = _jit_select(
            spec, dstate, sk,
            DeviceSelectionContext(fractions, losses, jnp.asarray(d_sched[t])))
        np.testing.assert_array_equal(
            np.asarray(hs), np.asarray(ds),
            err_msg=f"{name} seed={seed} round {t}")
        sv = (jnp.asarray(rng.standard_normal(m).astype(np.float32))
              if host.uses_shapley else None)
        hstate = host.update(hstate, np.asarray(hs), sv_round=sv)
        dstate = _jit_update(spec, dstate, jnp.asarray(ds), sv)
    # valuation state: counts/initialised exact; sv to jit-fusion ulp
    np.testing.assert_array_equal(np.asarray(hstate.valuation.counts),
                                  np.asarray(dstate.valuation.counts))
    np.testing.assert_array_equal(np.asarray(hstate.valuation.initialised),
                                  np.asarray(dstate.valuation.initialised))
    np.testing.assert_allclose(np.asarray(hstate.valuation.sv),
                               np.asarray(dstate.valuation.sv),
                               rtol=1e-6, atol=1e-7)
    np.testing.assert_array_equal(np.asarray(hstate.active),
                                  np.asarray(dstate.active))
    assert bool(hstate.frozen) == bool(dstate.frozen)
    return host, hstate, dstate


@pytest.mark.parametrize("seed", [0, 1])
@pytest.mark.parametrize("name", sorted(SELECTORS))
def test_host_device_selector_parity(name, seed):
    """Every registry strategy: the jittable device twin reproduces the
    host selector's per-round selections bit-for-bit across seeds."""
    _drive_both(name, seed)


def test_power_of_choice_explicit_d0_zero_parity():
    """Regression: d0=0 means 'd clamps to m every round' on both paths —
    it must not round-trip through selector_spec as the None sentinel."""
    spec = selector_spec(make_selector("power_of_choice", 9, 3, d0=0))
    assert poc_d_schedule(spec, 4).tolist() == [3, 3, 3, 3]
    _drive_both("power_of_choice", 0, rounds=4, d0=0)


def test_make_selector_spec_matches_host_instance():
    spec = make_selector_spec("ucb", 12, 4, c=2.5)
    assert spec.name == "ucb" and spec.c == 2.5
    assert spec == selector_spec(make_selector("ucb", 12, 4, c=2.5))
    assert spec.rr_rounds == 3 and spec.uses_shapley


@pytest.mark.parametrize("name", sorted(SELECTORS))
def test_spec_factory_agrees_with_host_oracle(name):
    """The native (host-free) spec registry reproduces selector_spec(host)
    exactly for every registry name at defaults AND with explicit kwargs —
    the contract that let selection_jax stop importing core.selection."""
    assert (make_selector_spec(name, 10, 3)
            == selector_spec(make_selector(name, 10, 3)))
    kw = {"power_of_choice": dict(decay=0.8, d0=7),
          "s_fedavg": dict(beta=0.3, temperature=2.0),
          "ucb": dict(c=1.5),
          "greedyfed": dict(averaging="exponential", alpha=0.7),
          "greedyfed_dropout": dict(averaging="exponential", alpha=0.7,
                                    drop_frac=0.3)}.get(name, {})
    assert (make_selector_spec(name, 10, 3, **kw)
            == selector_spec(make_selector(name, 10, 3, **kw)))


# ------------------------------------------------- dropout mask edge cases --
@pytest.mark.parametrize("drop_frac,expect_keep", [
    (0.0, 10),   # nothing drops: active stays full
    (1.0, 3),    # degenerate: n_keep clamps up to m
    (0.9, 3),    # round(0.1*10) = 1 < m: the n_keep < m clamp
])
def test_dropout_drop_frac_edges(drop_frac, expect_keep):
    n, m = 10, 3
    host = make_selector("greedyfed_dropout", n, m, seed=0,
                         drop_frac=drop_frac)
    spec = selector_spec(host)
    assert spec.n_keep == expect_keep
    hstate = host.init_state()
    dstate = init_device_state(spec, 0)
    ctx = SelectionContext(data_fractions=jnp.ones(n) / n)
    dctx = DeviceSelectionContext(jnp.ones(n) / n, jnp.zeros(n),
                                  jnp.asarray(0))
    rr = int(np.ceil(n / m))
    key = jax.random.key(0)
    for t in range(rr + 1):
        key, sk = jax.random.split(key)
        hs, hstate = host.select(hstate, sk, ctx)
        ds, dstate = _jit_select(spec, dstate, sk, dctx)
        np.testing.assert_array_equal(np.asarray(hs), np.asarray(ds))
        sv = jnp.asarray([float(i) for i in np.asarray(hs)])  # SV == id
        hstate = host.update(hstate, np.asarray(hs), sv_round=sv)
        dstate = _jit_update(spec, dstate, jnp.asarray(ds), sv)
    # post-RR: mask frozen at exactly n_keep highest-SV clients, both paths
    assert bool(hstate.frozen) and bool(dstate.frozen)
    assert int(hstate.active.sum()) == expect_keep
    np.testing.assert_array_equal(np.asarray(hstate.active),
                                  np.asarray(dstate.active))
    want_frac = 1.0 - expect_keep / n
    assert host.dropped_fraction(hstate) == pytest.approx(want_frac)
    assert float(device_dropped_fraction(dstate)) == pytest.approx(want_frac)
    # selections always come from the active set
    hs, hstate = host.select(hstate, jax.random.key(99), ctx)
    assert all(hstate.active[int(i)] for i in hs)


@pytest.mark.parametrize("seed", [0, 1])
def test_dropout_all_active_dropped_parity(seed):
    """Satellite: with the active-mask all-False (every remaining client
    dropped — reachable only by state surgery, since n_keep >= m), the
    all -inf masked scores fall back to the stable-argsort order on BOTH
    paths; host and device must still agree bit-for-bit."""
    n, m = 8, 3
    host = make_selector("greedyfed_dropout", n, m, seed=seed)
    spec = selector_spec(host)
    rng = np.random.default_rng(seed)
    sv = jnp.asarray(rng.standard_normal(n).astype(np.float32))
    hstate = host.init_state()
    hstate = hstate._replace(
        valuation=hstate.valuation._replace(sv=sv),
        round=spec.rr_rounds + 1,          # past RR: the greedy branch
        active=np.zeros(n, bool), frozen=True)
    dstate = init_device_state(spec, seed)
    dstate = dstate._replace(
        valuation=dstate.valuation._replace(sv=sv),
        round=jnp.asarray(spec.rr_rounds + 1, jnp.int32),
        active=jnp.zeros(n, bool), frozen=jnp.asarray(True))
    key = jax.random.key(seed + 41)
    hs, hstate = host.select(hstate, key, _ctx(n))
    ds, dstate = _jit_select(spec, dstate, key,
                             DeviceSelectionContext(jnp.ones(n) / n,
                                                    jnp.zeros(n),
                                                    jnp.asarray(0)))
    np.testing.assert_array_equal(np.asarray(hs), np.asarray(ds))
    assert len(set(int(i) for i in ds)) == m
    # the frozen all-False mask survives the round untouched on both paths
    assert not np.asarray(hstate.active).any()
    assert not np.asarray(dstate.active).any()
    assert float(device_dropped_fraction(dstate)) == 1.0


def test_sv_averaging_routed_through_selector_kwargs():
    """Satellite: sv_averaging/sv_alpha reach the selector spec via the
    factory, and explicit selector_kwargs win over the FLConfig knobs."""
    from repro.federated.server import FLConfig, setup_run
    small = dict(n_clients=4, m=2, rounds=1, n_train=120, n_val=40,
                 n_test=40)
    s = setup_run(FLConfig(selector="greedyfed", sv_averaging="exponential",
                           sv_alpha=0.25, **small))
    assert s.sel_spec.sv_mode == "exponential"
    assert s.sel_spec.sv_alpha == 0.25
    s = setup_run(FLConfig(selector="greedyfed_dropout",
                           sv_averaging="exponential", **small))
    assert s.sel_spec.sv_mode == "exponential"
    s = setup_run(FLConfig(selector="greedyfed", sv_averaging="exponential",
                           selector_kwargs={"averaging": "mean"}, **small))
    assert s.sel_spec.sv_mode == "mean"

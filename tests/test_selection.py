"""Selection strategies: RR initialisation coverage, greedy top-M, softmax
sampling validity, Power-of-Choice loss bias."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.selection import SelectionContext, make_selector


def _ctx(n, losses=None):
    return SelectionContext(
        data_fractions=jnp.ones(n) / n,
        local_losses=None if losses is None else jnp.asarray(losses))


def test_greedyfed_round_robin_covers_all_clients():
    n, m = 10, 3
    sel = make_selector("greedyfed", n, m, seed=1)
    state = sel.init_state()
    seen = set()
    rr_rounds = int(np.ceil(n / m))
    for t in range(rr_rounds):
        s, state = sel.select(state, jax.random.key(t), _ctx(n))
        seen.update(int(i) for i in s)
        state = sel.update(state, s, sv_round=jnp.zeros(m))
    assert seen == set(range(n)), "RR phase must value every client once"


def test_greedyfed_selects_top_sv_after_rr():
    n, m = 6, 2
    sel = make_selector("greedyfed", n, m, seed=0)
    state = sel.init_state()
    rr_rounds = int(np.ceil(n / m))
    for t in range(rr_rounds):
        s, state = sel.select(state, jax.random.key(t), _ctx(n))
        # hand clients k a known value == k
        state = sel.update(state, s, sv_round=jnp.asarray(
            [float(i) for i in s]))
    s, _ = sel.select(state, jax.random.key(99), _ctx(n))
    assert set(int(i) for i in s) == {n - 1, n - 2}, "greedy must pick top-M"


def test_ucb_prefers_unexplored_among_equal_values():
    n, m = 4, 1
    sel = make_selector("ucb", n, m, seed=0, c=10.0)
    state = sel.init_state()
    for t in range(4):  # RR
        s, state = sel.select(state, jax.random.key(t), _ctx(n))
        state = sel.update(state, s, sv_round=jnp.zeros(1))
    # select client 0 twice more -> its UCB bonus shrinks
    for t in range(2):
        state = sel.update(state, np.array([0]), sv_round=jnp.zeros(1))
    s, _ = sel.select(state, jax.random.key(9), _ctx(n))
    assert int(s[0]) != 0


def test_power_of_choice_picks_highest_loss():
    n, m = 8, 2
    sel = make_selector("power_of_choice", n, m, seed=0, d0=8, decay=1.0)
    state = sel.init_state()
    losses = np.arange(n, dtype=np.float32)
    s, _ = sel.select(state, jax.random.key(0), _ctx(n, losses))
    assert set(int(i) for i in s) <= set(range(n))
    assert min(int(i) for i in s) >= n - 4, "should pick from high-loss tail"


def test_sfedavg_returns_valid_distinct_clients():
    n, m = 10, 4
    sel = make_selector("s_fedavg", n, m, seed=0)
    state = sel.init_state()
    s, _ = sel.select(state, jax.random.key(0), _ctx(n))
    assert len(set(int(i) for i in s)) == m


def test_unknown_selector_raises():
    with pytest.raises(ValueError):
        make_selector("nope", 4, 2)

"""shapley_impl="batched" (TPU-native GTG variant) through the server loop."""
import numpy as np

from repro.federated.client import ClientConfig
from repro.federated.server import FLConfig, run_federated

FAST = dict(n_clients=8, m=2, rounds=6, n_train=800, n_val=150, n_test=200,
            eval_every=3, shapley_max_iters=32,
            client=ClientConfig(epochs=2, batches_per_epoch=2, batch_size=16))


def test_batched_shapley_impl_trains():
    res = run_federated(FLConfig(dataset="mnist", selector="greedyfed",
                                 shapley_impl="batched", **FAST))
    assert np.isfinite(res.final_acc) and res.final_acc > 0.2
    assert res.shapley_evals > 0
    assert np.isfinite(res.sv_final).all()


def test_dropout_selector_through_server():
    res = run_federated(FLConfig(dataset="mnist",
                                 selector="greedyfed_dropout", **FAST))
    assert np.isfinite(res.final_acc) and res.final_acc > 0.2

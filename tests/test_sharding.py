"""Multi-device sharding tests.

These run in SUBPROCESSES with XLA_FLAGS=--xla_force_host_platform_device_count=8
because the main pytest process must keep seeing exactly 1 CPU device (the
smoke tests and benches depend on it, and jax locks the device count at
first init).
"""
import json
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str) -> subprocess.CompletedProcess:
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=SRC)
    return subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=900)


def test_debug_mesh_train_prefill_decode_lower():
    """Every family lowers+compiles train/prefill/decode on a 2x4 mesh."""
    code = """
import dataclasses, jax
from repro.configs import get_config
from repro.launch.shapes import InputShape, pad_vocab
from repro.launch import dryrun as DR
from repro.launch.compat import named_shardings, set_mesh
from repro.launch.mesh import make_debug_mesh
from repro.launch.sharding import launch_cfg

mesh = make_debug_mesh((2, 4), ("data", "model"))
shapes = [InputShape("t", 256, 8, "train"), InputShape("p", 256, 8, "prefill"),
          InputShape("d", 256, 8, "decode")]
for arch in ["tinyllama_1_1b", "qwen3_moe_30b_a3b", "mamba2_370m",
             "hymba_1_5b", "whisper_medium"]:
    c0 = get_config(arch)
    c0 = dataclasses.replace(
        c0, n_layers=2, encoder_layers=min(c0.encoder_layers, 2), d_model=512,
        n_heads=8 if c0.n_heads else 0,
        n_kv_heads=(4 if c0.n_kv_heads >= 4 else c0.n_kv_heads) if c0.n_heads else 0,
        head_dim=64 if c0.n_heads else 0,
        d_ff=min(c0.d_ff, 1024) if c0.d_ff else 0, vocab=1024,
        n_experts=min(c0.n_experts, 8),
        window=min(c0.window, 64) if c0.window else 0,
        n_frontend_tokens=min(c0.n_frontend_tokens, 16))
    for shape in shapes:
        cfg = launch_cfg(pad_vocab(c0), mesh, shape)
        fn, args, in_s, out_s = DR.build_step(cfg, shape, mesh)
        with set_mesh(mesh):
            jax.jit(fn, in_shardings=named_shardings(mesh, in_s),
                    out_shardings=named_shardings(mesh, out_s)
                    ).lower(*args).compile()
        print("OK", arch, shape.name)
print("ALL_LOWERED")
"""
    p = _run(code)
    assert "ALL_LOWERED" in p.stdout, p.stdout + p.stderr


def test_sharded_execution_matches_single_device():
    """A sharded train step produces the same loss as unsharded (8 devices)."""
    code = """
import dataclasses, jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.launch.shapes import InputShape, pad_vocab
from repro.launch import dryrun as DR
from repro.launch.compat import set_mesh
from repro.launch.mesh import make_debug_mesh
from repro.launch.sharding import launch_cfg
from repro.models.lm import model as M

c0 = get_config("tinyllama_1_1b").reduced()
c0 = dataclasses.replace(c0, vocab=512, dtype="float32")
key = jax.random.key(0)
params = M.init_params(c0, key)
batch = {"tokens": jax.random.randint(key, (8, 64), 0, c0.vocab)}
loss_single = float(M.loss_fn(c0, params, batch))

mesh = make_debug_mesh((2, 4), ("data", "model"))
shape = InputShape("t", 64, 8, "train")
cfg = launch_cfg(c0, mesh, shape)
with set_mesh(mesh):
    loss_sharded = float(jax.jit(lambda p, b: M.loss_fn(cfg, p, b))(params, batch))
print("SINGLE", loss_single, "SHARDED", loss_sharded)
assert abs(loss_single - loss_sharded) < 1e-3, (loss_single, loss_sharded)
print("MATCH")
"""
    p = _run(code)
    assert "MATCH" in p.stdout, p.stdout + p.stderr


def test_parallel_client_round_lowers_on_mesh():
    """The client-parallel FL round shards over the data axis."""
    code = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.federated.client import ClientConfig
from repro.federated.sim import parallel_client_round
from repro.launch.compat import named_shardings, set_mesh
from repro.launch.mesh import make_debug_mesh
from repro.models.mlp_cnn import make_mlp

mesh = make_debug_mesh((8,), ("data",))
model = make_mlp(input_dim=32, hidden=(16,), n_classes=4)
ccfg = ClientConfig(epochs=1, batches_per_epoch=1, batch_size=4)
key = jax.random.key(0)
params = model.init(key)
M_sel, cap = 8, 16
xs = jax.random.normal(key, (M_sel, cap, 32))
ys = jax.random.randint(key, (M_sel, cap), 0, 4)
nv = jnp.full((M_sel,), cap)
ek = jnp.full((M_sel,), 1)
sg = jnp.zeros((M_sel,))
keys = jax.random.split(key, M_sel)

with set_mesh(mesh):
    fn = jax.jit(lambda *a: parallel_client_round(model, ccfg, *a),
                 in_shardings=named_shardings(
                     mesh, (None, P("data"), P("data"), P("data"),
                            P("data"), P("data"), P("data"))))
    stacked, new_params = fn(params, xs, ys, nv, ek, sg, keys)
hlo = jax.jit(lambda *a: parallel_client_round(model, ccfg, *a)).lower(
    params, xs, ys, nv, ek, sg, keys).as_text()
assert np.isfinite(np.asarray(jax.tree.leaves(new_params)[0])).all()
print("PARALLEL_ROUND_OK")
"""
    p = _run(code)
    assert "PARALLEL_ROUND_OK" in p.stdout, p.stdout + p.stderr

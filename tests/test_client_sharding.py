"""Client-axis sharding (DESIGN.md §16): sharded vs dense bit-identity.

These run in SUBPROCESSES with XLA_FLAGS=--xla_force_host_platform_device_count=8
because the main pytest process must keep seeing exactly 1 CPU device (the
smoke tests and benches depend on it, and jax locks the device count at
first init).

The contract under test: with `clients_shards > 1` the per-client state
(padded data stacks, n_valid, sigma, straggler tables, selector vectors)
lives sharded over the "clients" mesh axis, selection runs on the gathered
global view, and every observable output — selections, params, eval curve,
final Shapley values — is BITWISE identical to the dense single-device run
at equal config.  Gathers copy bits (cross-shard floats go through the
bitcast-uint psum), so the comparisons below are exact, not approximate.
"""
import os
import subprocess
import sys

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str) -> subprocess.CompletedProcess:
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=SRC)
    return subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=900)


_COMPARE = """
def flat(params):
    import jax, numpy as np
    return np.concatenate([np.asarray(x).ravel()
                           for x in jax.tree.leaves(params)])

def check_same(a, b, label):
    import numpy as np
    assert len(a.selections) == len(b.selections), label
    for ra, rb in zip(a.selections, b.selections):
        assert (np.asarray(ra) == np.asarray(rb)).all(), (label, "selections")
    assert (flat(a.params) == flat(b.params)).all(), (label, "params")
    assert a.test_acc == b.test_acc, (label, "eval curve")
    assert a.val_loss == b.val_loss, (label, "val curve")
    assert (np.asarray(a.sv_final) == np.asarray(b.sv_final)).all(), label
    assert (a.selection_counts == b.selection_counts).all(), label
"""


def test_solo_scan_sharded_matches_dense_bitwise():
    """run_federated with clients_shards in {1, 2, 8} x 2 seeds equals the
    dense scan run bitwise — N=13 is not a multiple of 2 or 8, so the
    zero-padding + slice-back path is exercised too."""
    code = _COMPARE + """
import dataclasses
from repro.federated.client import ClientConfig
from repro.federated.server import FLConfig, run_federated

base = FLConfig(n_clients=13, m=4, rounds=8, selector="greedyfed",
                engine="scan", eval_every=4, n_train=400, n_val=60,
                n_test=60, straggler_frac=0.3, privacy_sigma=0.05,
                client=ClientConfig(epochs=1, batch_size=8, lr=0.05))
for seed in (0, 1):
    cfg = dataclasses.replace(base, seed=seed)
    dense = run_federated(cfg)
    for shards in (1, 2, 8):
        sh = run_federated(dataclasses.replace(cfg, clients_shards=shards))
        check_same(dense, sh, ("seed", seed, "shards", shards))
        print("OK", seed, shards)
print("SOLO_SHARDED_BITWISE")
"""
    p = _run(code)
    assert "SOLO_SHARDED_BITWISE" in p.stdout, p.stdout + p.stderr


def test_grid_sharded_matches_dense_and_resumes_bitwise():
    """Segmented grid with a 1x2 (replica x clients) mesh: every cell
    bitwise-equal to the dense grid, including after a kill (max_segments=1)
    and checkpoint resume."""
    code = _COMPARE + """
import dataclasses, tempfile
from repro.federated.client import ClientConfig
from repro.federated.server import FLConfig
from repro.grid.runner import run_grid
from repro.grid.spec import GridSpec

base = FLConfig(n_clients=13, m=4, rounds=8, selector="greedyfed",
                engine="scan", eval_every=4, n_train=400, n_val=60,
                n_test=60, straggler_frac=0.3, privacy_sigma=0.05,
                client=ClientConfig(epochs=1, batch_size=8, lr=0.05))
mk = lambda shards: GridSpec.product(
    dataclasses.replace(base, clients_shards=shards),
    selectors=["greedyfed", "power_of_choice"], seeds=[0, 1])

dense = run_grid(mk(1), rounds_per_segment=4, shard=False)
sharded = run_grid(mk(2), rounds_per_segment=4)
for cell, a, b in zip(dense.spec.cells, dense.results, sharded.results):
    check_same(a, b, (cell.selector, cell.seed))
    print("OK", cell.selector, cell.seed)
print("GRID_SHARDED_BITWISE")

with tempfile.TemporaryDirectory() as ckpt:
    partial = run_grid(mk(2), rounds_per_segment=4, checkpoint_dir=ckpt,
                       max_segments=1)
    assert partial is None
    resumed = run_grid(mk(2), rounds_per_segment=4, checkpoint_dir=ckpt)
    for cell, a, b in zip(dense.spec.cells, dense.results, resumed.results):
        check_same(a, b, ("resume", cell.selector, cell.seed))
    # the checkpointed prefix really was restored, not recomputed
    assert resumed.dispatches < sharded.dispatches
print("GRID_RESUME_BITWISE")
"""
    p = _run(code)
    assert "GRID_SHARDED_BITWISE" in p.stdout, p.stdout + p.stderr
    assert "GRID_RESUME_BITWISE" in p.stdout, p.stdout + p.stderr


def test_cross_shard_cohort_take_bitwise():
    """cohort_take under shard_map over the clients axis copies bits:
    -0.0 and NaN payloads survive the bitcast-uint psum path; integer
    tables take the zero-and-psum path."""
    code = """
from functools import partial
import jax, jax.numpy as jnp, numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P
from repro.kernels.cohort_gather import cohort_take

mesh = Mesh(np.asarray(jax.devices()), ("clients",))
n, d = 16, 33
table = np.random.default_rng(0).standard_normal((n, d)).astype(np.float32)
table[0, 0] = -0.0
table[1, 1] = np.nan
table[15, 2] = np.float32(np.inf)
ids = np.asarray([0, 1, 7, 15, 1], np.int32)
take = shard_map(partial(cohort_take, axis_name="clients"), mesh=mesh,
                 in_specs=(P("clients"), P()), out_specs=P(),
                 check_rep=False)
got = np.asarray(take(jnp.asarray(table), jnp.asarray(ids)))
assert (got.view(np.uint32) == table[ids].view(np.uint32)).all()

ints = (np.arange(n, dtype=np.int32) * 3 - 7)
got_i = np.asarray(take(jnp.asarray(ints), jnp.asarray(ids)))
assert (got_i == ints[ids]).all()
print("CROSS_SHARD_TAKE_BITWISE")
"""
    p = _run(code)
    assert "CROSS_SHARD_TAKE_BITWISE" in p.stdout, p.stdout + p.stderr

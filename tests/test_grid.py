"""repro.grid: partitioned / segmented / resumable / sharded grid runner
(DESIGN.md §12), plus the straggler-stream unification (straggler_rev)."""
import dataclasses
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.federated.client import ClientConfig
from repro.federated.server import FLConfig, run_federated
from repro.grid import GridCell, GridSpec, run_grid

TINY = dict(n_clients=8, m=3, rounds=6, n_train=600, n_val=100, n_test=100,
            eval_every=3,
            client=ClientConfig(epochs=2, batches_per_epoch=2, batch_size=16))


def _flat(params):
    return np.concatenate([np.ravel(np.asarray(l))
                           for l in jax.tree.leaves(params)])


def _base(**kw):
    kw = dict(selector="greedyfed", engine="scan", shapley_max_iters=10,
              **TINY) | kw
    return FLConfig(**kw)


def _assert_bitwise(a, b):
    assert len(a.selections) == len(b.selections)
    for t, (sa, sb) in enumerate(zip(a.selections, b.selections)):
        np.testing.assert_array_equal(sa, sb, err_msg=f"round {t}")
    np.testing.assert_array_equal(_flat(a.params), _flat(b.params))


# ------------------------------------------------------------------ spec --
def test_per_cell_eval_cadence_matches_solo():
    """ROADMAP 'eval under the replica vmap', LIFTED (DESIGN.md §13): grid
    cells may override eval_every.  The replica vmap runs the masked eval
    round wherever ANY replica's mask is set, masks out the other
    replicas' writes, and every cell's eval curve reproduces its solo run
    — still one dispatch per partition."""
    base = _base(selector="fedavg")
    spec = GridSpec(base, (
        GridCell("fedavg", 0),                                # every 3
        GridCell("fedavg", 0, overrides={"eval_every": 2}),
        GridCell("fedavg", 1, overrides={"eval_every": 100})))  # final only
    grid = run_grid(spec)
    assert len(grid.partitions) == 1 and grid.results[0].dispatches == 1
    for cell, res in zip(spec.cells, grid.results):
        solo = run_federated(dataclasses.replace(
            base, seed=cell.seed, **dict(cell.overrides)))
        _assert_bitwise(solo, res)
        assert [t for t, _ in res.test_acc] == [t for t, _ in solo.test_acc]
        np.testing.assert_allclose([a for _, a in res.test_acc],
                                   [a for _, a in solo.test_acc],
                                   atol=1e-6)
    # per-replica curves genuinely differ in shape
    assert [len(r.test_acc) for r in grid.results] == [2, 3, 1]


def test_per_cell_eval_cadence_segmented_and_resumed(tmp_path):
    """Mixed cadences survive segmentation + checkpoint/resume: the
    eval-slot counter crosses segment boundaries in the carry."""
    base = _base(selector="fedavg")
    spec = GridSpec(base, (
        GridCell("fedavg", 0),
        GridCell("fedavg", 0, overrides={"eval_every": 2})))
    whole = run_grid(spec)
    seg = run_grid(spec, rounds_per_segment=2)
    partial = run_grid(spec, rounds_per_segment=2,
                       checkpoint_dir=str(tmp_path), max_segments=1)
    assert partial is None
    resumed = run_grid(spec, rounds_per_segment=2,
                       checkpoint_dir=str(tmp_path))
    for a, b in zip(whole.results, seg.results):
        _assert_bitwise(a, b)
        assert a.test_acc == b.test_acc
    for a, b in zip(whole.results, resumed.results):
        _assert_bitwise(a, b)
        assert a.test_acc == b.test_acc


def test_static_field_mismatch_rejected():
    spec = GridSpec(_base(), (GridCell("fedavg", 0),
                              GridCell("fedavg", 1,
                                       overrides={"sv_chunk": 2})))
    with pytest.raises(ValueError, match="jit-static FLConfig field"):
        run_grid(spec)


def test_unknown_codec_rejected():
    spec = GridSpec(_base(), (GridCell("fedavg", 0,
                                       overrides={"upload_codec": "zstd"}),))
    with pytest.raises(ValueError, match="unknown upload_codec"):
        run_grid(spec)


def test_segment_plan_must_divide():
    with pytest.raises(ValueError, match="must divide"):
        run_grid(GridSpec.product(_base(), seeds=(0,)),
                 rounds_per_segment=4)   # 4 does not divide rounds=6


# ------------------------------------------------------- partitioned grid --
def test_partitioned_mixed_grid_matches_solo():
    """A greedyfed+power_of_choice+fedavg grid splits into 3 capability
    partitions; every cell still reproduces its solo scan run, results
    come back in cell order, and the fedavg partition never computes SV."""
    base = _base()
    grid = run_grid(GridSpec.product(
        base, selectors=["greedyfed", "power_of_choice", "fedavg"],
        seeds=(0,)))
    assert [p.label for p in grid.partitions] == ["sv", "losses", "plain"]
    assert [r.config.selector for r in grid.results] == [
        "greedyfed", "power_of_choice", "fedavg"]
    for r in grid.results:
        solo = run_federated(dataclasses.replace(
            base, selector=r.config.selector))
        _assert_bitwise(solo, r)
        assert r.dispatches == 1
    evals = {r.config.selector: r.shapley_evals for r in grid.results}
    assert evals["greedyfed"] > 0
    assert evals["power_of_choice"] == 0 and evals["fedavg"] == 0
    sv, losses, plain = grid.partitions
    assert sv.needs_sv and not plain.needs_sv
    assert losses.uses_local_losses and not losses.needs_sv
    assert plain.shapley_evals == 0


def test_mixed_codec_grid_matches_solo_and_resumes(tmp_path):
    """The §18 lift: `upload_codec` is partition-varying instead of
    grid-static.  A selection x compression grid splits into one
    partition per (capability, codec) pair — each codec compiles its own
    executable — and every cell bitwise-reproduces the solo scan run at
    its codec.  The partitioning also survives a segmented kill/resume."""
    base = _base()
    spec = GridSpec(base, (
        GridCell("greedyfed", 0, overrides={"upload_codec": "quant8"}),
        GridCell("fedavg", 0),
        GridCell("fedavg", 0, overrides={"upload_codec": "quant8"}),
        GridCell("fedavg", 0, overrides={"upload_codec": "topk"})))
    grid = run_grid(spec)
    assert [p.label for p in grid.partitions] == [
        "sv+quant8", "plain", "plain+quant8", "plain+topk"]
    assert [p.upload_codec for p in grid.partitions] == [
        "quant8", "identity", "quant8", "topk"]
    for cell, res in zip(spec.cells, grid.results):
        solo = run_federated(dataclasses.replace(
            base, selector=cell.selector, seed=cell.seed,
            **dict(cell.overrides)))
        _assert_bitwise(solo, res)
        assert res.upload_bytes == solo.upload_bytes
    # compression genuinely changed the trajectory and the ledger
    assert not np.array_equal(_flat(grid.results[1].params),
                              _flat(grid.results[2].params))
    assert grid.results[2].upload_bytes < grid.results[1].upload_bytes
    # kill after one segment dispatch, resume, still bitwise
    ckpt = str(tmp_path)
    partial = run_grid(spec, rounds_per_segment=2, checkpoint_dir=ckpt,
                       max_segments=1)
    assert partial is None
    resumed = run_grid(spec, rounds_per_segment=2, checkpoint_dir=ckpt)
    for a, b in zip(grid.results, resumed.results):
        _assert_bitwise(a, b)
        assert a.test_acc == b.test_acc


def test_grid_knob_overrides_match_solo():
    """Per-cell knob overrides (privacy sigma here) become per-replica
    operands: each cell reproduces the solo run at its knob value."""
    base = _base(selector="fedavg")
    spec = GridSpec(base, (
        GridCell("fedavg", 0),
        GridCell("fedavg", 0, overrides={"privacy_sigma": 0.1})))
    grid = run_grid(spec)
    clean = run_federated(base)
    noisy = run_federated(dataclasses.replace(base, privacy_sigma=0.1))
    _assert_bitwise(clean, grid.results[0])
    _assert_bitwise(noisy, grid.results[1])
    assert not np.allclose(_flat(grid.results[0].params),
                           _flat(grid.results[1].params))


# -------------------------------------------------------- segmented scan --
@pytest.mark.parametrize("k", [2, 3])
def test_segmented_grid_bit_identical(k):
    """Any K dividing T chains T/K dispatches of one compiled segment and
    reproduces the unsegmented run bit-for-bit (selections, params, eval
    history) — the acceptance contract of DESIGN.md §12."""
    spec = GridSpec.product(_base(), selectors=["greedyfed", "fedavg"],
                            seeds=(0,))
    whole = run_grid(spec)
    seg = run_grid(spec, rounds_per_segment=k)
    assert seg.n_segments == TINY["rounds"] // k
    for a, b in zip(whole.results, seg.results):
        _assert_bitwise(a, b)
        assert a.test_acc == b.test_acc
        assert b.dispatches == seg.n_segments
        assert a.shapley_evals == b.shapley_evals


def test_kill_at_segment_boundary_resumes_bit_identical(tmp_path):
    """max_segments simulates a kill after the first segment dispatch; the
    rerun restores the checkpointed prefix and finishes bit-identically —
    without re-dispatching the restored segments."""
    spec = GridSpec.product(_base(), selectors=["greedyfed", "fedavg"],
                            seeds=(0,))
    ckpt = str(tmp_path)
    whole = run_grid(spec)
    partial = run_grid(spec, rounds_per_segment=2, checkpoint_dir=ckpt,
                       max_segments=1)
    assert partial is None                      # killed mid-run
    assert any(f.endswith(".npz") for f in os.listdir(ckpt))
    resumed = run_grid(spec, rounds_per_segment=2, checkpoint_dir=ckpt)
    for a, b in zip(whole.results, resumed.results):
        _assert_bitwise(a, b)
        assert a.test_acc == b.test_acc
    # the sv partition dispatched 1 segment pre-kill, so the resumed run
    # only paid for what was missing
    assert resumed.partitions[0].dispatches == resumed.n_segments - 1
    # a DIFFERENT grid must not silently adopt these checkpoints (segment
    # snapshots only differ by shapes, which a knob change preserves)
    other = GridSpec.product(_base(privacy_sigma=0.1),
                             selectors=["greedyfed", "fedavg"], seeds=(0,))
    with pytest.raises(ValueError, match="DIFFERENT grid"):
        run_grid(other, rounds_per_segment=2, checkpoint_dir=ckpt)
    # checkpoints from an older SegmentCarry layout fail with a version-
    # skew error, not an opaque structure mismatch (PR-3 dirs carried no
    # carry_format key => format 1)
    import json
    gj = os.path.join(ckpt, "grid.json")
    with open(gj) as f:
        meta = json.load(f)
    del meta["carry_format"]
    with open(gj, "w") as f:
        json.dump(meta, f)
    with pytest.raises(ValueError, match="carry format"):
        run_grid(spec, rounds_per_segment=2, checkpoint_dir=ckpt)


# ------------------------------------------------- straggler stream parity --
def test_straggler_stream_identical_across_engines():
    """straggler_rev=1 (default) routes every engine through the pre-drawn
    (T, N) table: loop, batched, and scan are now STREAM-identical under
    straggler_frac > 0 (ROADMAP 'scan + random stragglers stream parity')."""
    cfg = dict(TINY, selector="greedyfed", shapley_max_iters=10,
               straggler_frac=0.5)
    loop = run_federated(FLConfig(engine="loop", **cfg))
    batched = run_federated(FLConfig(engine="batched", **cfg))
    scan = run_federated(FLConfig(engine="scan", **cfg))
    _assert_bitwise_allclose(loop, batched)
    _assert_bitwise_allclose(loop, scan)
    assert loop.shapley_evals == scan.shapley_evals


def _assert_bitwise_allclose(a, b, atol=1e-5):
    for t, (sa, sb) in enumerate(zip(a.selections, b.selections)):
        np.testing.assert_array_equal(sa, sb, err_msg=f"round {t}")
    np.testing.assert_allclose(_flat(a.params), _flat(b.params), atol=atol)


def test_straggler_rev0_keeps_legacy_stream():
    """The paper-faithful lazy per-selection draw survives behind
    straggler_rev=0: loop and batched still agree with each other (same
    host stream), budgets stay in U{1..E}, and the stream genuinely
    differs from the rev=1 table path (distribution-level fork)."""
    cfg = dict(TINY, selector="fedavg", straggler_frac=0.5)
    legacy = run_federated(FLConfig(engine="loop", straggler_rev=0, **cfg))
    legacy_b = run_federated(FLConfig(engine="batched", straggler_rev=0,
                                      **cfg))
    _assert_bitwise_allclose(legacy, legacy_b)
    assert np.isfinite(_flat(legacy.params)).all()
    rev1 = run_federated(FLConfig(engine="loop", **cfg))
    for sa, sb in zip(legacy.selections, rev1.selections):
        np.testing.assert_array_equal(sa, sb)   # selection keys unchanged
    assert not np.array_equal(_flat(legacy.params), _flat(rev1.params))


# ------------------------------------------------------- sharded replicas --
def test_sharded_grid_on_debug_mesh():
    """The replica axis shards over the forced-host 8-device debug mesh
    (subprocess: the main pytest process must keep seeing 1 CPU device);
    a 4-replica partition lands on 4 devices and matches the unsharded
    run bit-for-bit on selections."""
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    code = """
import numpy as np, jax
assert len(jax.devices()) == 8
from repro.federated.client import ClientConfig
from repro.federated.server import FLConfig
from repro.grid import GridSpec, run_grid
from repro.launch.mesh import REPLICA_AXIS, make_replica_mesh
base = FLConfig(selector="fedavg", engine="scan", n_clients=8, m=3,
                rounds=4, n_train=400, n_val=80, n_test=80, eval_every=2,
                client=ClientConfig(epochs=1, batches_per_epoch=2,
                                    batch_size=16))
mesh = make_replica_mesh(4)
assert mesh is not None and mesh.shape[REPLICA_AXIS] == 4
spec = GridSpec.product(base, seeds=(0, 1, 2, 3))
sharded = run_grid(spec, rounds_per_segment=2, shard=True)
plain = run_grid(spec, shard=False)
def flat(p):
    return np.concatenate([np.ravel(np.asarray(l))
                           for l in jax.tree.leaves(p)])
for a, b in zip(sharded.results, plain.results):
    for sa, sb in zip(a.selections, b.selections):
        np.testing.assert_array_equal(sa, sb)
    np.testing.assert_allclose(flat(a.params), flat(b.params), atol=1e-6)
print("SHARDED_GRID_OK")
"""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=src)
    p = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=900)
    assert "SHARDED_GRID_OK" in p.stdout, p.stdout + p.stderr


# ------------------------------------------------------------- accessors --
def test_grid_result_accessors():
    spec = GridSpec.product(_base(selector="fedavg"), seeds=(0, 1))
    grid = run_grid(spec)
    assert grid.cell("fedavg", 1).config.seed == 1
    assert len(grid.select("fedavg")) == 2
    mean, std = grid.acc_summary()["fedavg"]
    assert 0.0 <= mean <= 1.0 and std >= 0.0
    with pytest.raises(KeyError):
        grid.cell("ucb", 0)


# -------------------------------------------------- §19 cell isolation --
def test_failing_partition_degrades_not_kills(monkeypatch):
    """A raising partition becomes per-cell CellFailure entries; the
    OTHER partitions' cells still run and stay bit-identical to solo."""
    import repro.grid.runner as runner
    from repro.grid import CellFailure
    from repro.telemetry import Telemetry, validate_events

    real = runner.run_segments

    def sabotage(model, ccfg, scan_spec, batch, **kw):
        if kw.get("tag") == "p0-":           # first partition only
            raise RuntimeError("injected partition failure")
        return real(model, ccfg, scan_spec, batch, **kw)

    monkeypatch.setattr(runner, "run_segments", sabotage)
    base = _base()
    # greedyfed (needs_sv) and fedavg land in different partitions
    spec = GridSpec(base, (GridCell("greedyfed", 0), GridCell("fedavg", 0)))
    tel = Telemetry()
    grid = run_grid(spec, telemetry=tel)
    assert len(grid.failures) == 1
    fail = grid.failures[0]
    assert isinstance(fail, CellFailure)
    assert "injected partition failure" in fail.error
    assert "RuntimeError" in fail.traceback
    assert np.isnan(fail.final_acc) and fail.upload_bytes == 0
    # the surviving cell is untouched by its neighbour's failure
    survivor = [r for r in grid.results
                if not isinstance(r, CellFailure)]
    assert len(survivor) == 1
    solo = run_federated(dataclasses.replace(base, selector="fedavg"))
    _assert_bitwise(solo, survivor[0])
    # acc_summary skips failures; cell_failed is on the event stream
    assert set(grid.acc_summary()) == {"fedavg"}
    validate_events(tel.events)
    failed_evs = [ev for ev in tel.events if ev["event"] == "cell_failed"]
    assert len(failed_evs) == 1 and failed_evs[0]["cell"] == fail.cell


def test_isolation_opt_out_raises(monkeypatch):
    import repro.grid.runner as runner

    def boom(*a, **kw):
        raise RuntimeError("injected partition failure")

    monkeypatch.setattr(runner, "run_segments", boom)
    spec = GridSpec.product(_base(selector="fedavg"), seeds=(0,))
    with pytest.raises(RuntimeError, match="injected"):
        run_grid(spec, isolate_cells=False)


def test_invalid_grid_still_raises_before_isolation():
    """Pre-dispatch validation (static-field mismatch) is a programming
    error, not a cell fault: it must raise even with isolation on."""
    spec = GridSpec(_base(selector="fedavg"), (
        GridCell("fedavg", 0),
        GridCell("fedavg", 1, overrides={"n_clients": 16})))
    with pytest.raises(ValueError, match="jit-static"):
        run_grid(spec, isolate_cells=True)

"""Cumulative-SV tracking (Alg. 1 lines 11-12) and the beyond-paper
SV-feedback dropout selector (via the runtime selection_jax stack)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.selection_jax import (
    DeviceSelectionContext, device_dropped_fraction, device_select,
    device_update, init_device_state, make_selector_spec,
)
from repro.core.valuation import init_valuation, update_valuation


def test_mean_update_is_running_mean():
    st = init_valuation(4)
    sel = jnp.array([1, 2])
    st = update_valuation(st, sel, jnp.array([2.0, 4.0]), mode="mean")
    st = update_valuation(st, sel, jnp.array([4.0, 0.0]), mode="mean")
    np.testing.assert_allclose(np.asarray(st.sv)[[1, 2]], [3.0, 2.0])
    assert st.counts[1] == 2 and st.counts[0] == 0


def test_exponential_update_seeds_with_first_value():
    st = init_valuation(3)
    st = update_valuation(st, jnp.array([0]), jnp.array([10.0]),
                          mode="exponential", alpha=0.9)
    # first observation is taken verbatim, not blended with the 0 init
    assert float(st.sv[0]) == 10.0
    st = update_valuation(st, jnp.array([0]), jnp.array([0.0]),
                          mode="exponential", alpha=0.9)
    np.testing.assert_allclose(float(st.sv[0]), 9.0)


def test_unselected_clients_untouched():
    st = init_valuation(5)
    st = update_valuation(st, jnp.array([3]), jnp.array([7.0]), mode="mean")
    assert float(st.sv[0]) == 0.0 and not bool(st.initialised[0])
    assert bool(st.initialised[3])


def test_dropout_selector_drops_bottom_and_saves_comm():
    n, m = 10, 2
    spec = make_selector_spec("greedyfed_dropout", n, m, drop_frac=0.5)
    state = init_device_state(spec, seed=0)
    ctx = DeviceSelectionContext(data_fractions=jnp.ones(n) / n,
                                 local_losses=jnp.zeros(n),
                                 poc_d=jnp.asarray(0))
    rr = int(np.ceil(n / m))
    for t in range(rr):
        s, state = device_select(spec, state, jax.random.key(t), ctx)
        # client k earns SV == k
        state = device_update(spec, state, s,
                              jnp.asarray([float(i) for i in s]))
    s, state = device_select(spec, state, jax.random.key(99), ctx)
    active = np.flatnonzero(np.asarray(state.active))
    assert len(active) == 5
    assert set(active.tolist()) == {5, 6, 7, 8, 9}, "bottom half must drop"
    assert set(int(i) for i in s) == {8, 9}
    assert float(device_dropped_fraction(state)) == 0.5

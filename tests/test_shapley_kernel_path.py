"""Device GTG-Shapley through the Pallas kernel paths (interpret):
the dense (weighted_avg, §8) and streaming (prefix_avg, §14) variants
must agree with the serial estimator's target."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregation import tree_stack
from repro.core.shapley import exact_shapley
from repro.core.shapley_batched import (
    gtg_shapley_batched, gtg_shapley_streaming, make_batched_mlp_utility,
    prefix_weight_matrix,
)
from repro.models.mlp_cnn import make_mlp


def test_prefix_weight_matrix_rows_are_prefix_averages():
    perms = jnp.array([[2, 0, 1]])
    n_k = jnp.array([1.0, 1.0, 2.0])
    w = prefix_weight_matrix(perms, n_k)   # (1, 3, 3)
    np.testing.assert_allclose(np.asarray(w[0, 0]), [0, 0, 1.0])          # {2}
    np.testing.assert_allclose(np.asarray(w[0, 1]), [1/3, 0, 2/3])        # {0,2}
    np.testing.assert_allclose(np.asarray(w[0, 2]), [0.25, 0.25, 0.5])    # all


def test_batched_shapley_kernel_path_on_mlp_utility(key):
    """End-to-end: MLP clients, ce_loss-kernel utility, weighted_avg kernel."""
    model = make_mlp(input_dim=16, hidden=(8,), n_classes=4)
    m = 3
    clients = [model.init(jax.random.key(i)) for i in range(m)]
    stacked = tree_stack(clients)
    n_k = jnp.array([5.0, 10.0, 15.0])
    w_prev = model.init(jax.random.key(99))
    x_val = jax.random.normal(key, (32, 16))
    y_val = jax.random.randint(key, (32,), 0, 4)

    def utility(p):
        return -model.loss(p, x_val, y_val)

    batched = make_batched_mlp_utility(model, x_val, y_val)
    sv_k, stats = gtg_shapley_batched(
        stacked, n_k, w_prev, utility, batched, jax.random.key(0),
        n_perms=256, use_kernel=True)
    sv_exact = exact_shapley(stacked, n_k, w_prev, utility)
    np.testing.assert_allclose(np.asarray(sv_k), np.asarray(sv_exact),
                               atol=0.05)
    # additivity survives the kernel path
    np.testing.assert_allclose(float(jnp.sum(sv_k)),
                               float(jnp.sum(sv_exact)), atol=1e-3)


def test_streaming_kernel_path_on_mlp_utility(key):
    """End-to-end streaming on real model pytrees: prefix_avg models,
    ce_loss-kernel utility, every chunking bit-identical, dense-path and
    exact-oracle agreement."""
    model = make_mlp(input_dim=16, hidden=(8,), n_classes=4)
    m = 3
    clients = [model.init(jax.random.key(i)) for i in range(m)]
    stacked = tree_stack(clients)
    n_k = jnp.array([5.0, 10.0, 15.0])
    w_prev = model.init(jax.random.key(99))
    x_val = jax.random.normal(key, (32, 16))
    y_val = jax.random.randint(key, (32,), 0, 4)

    def utility(p):
        return -model.loss(p, x_val, y_val)

    batched = make_batched_mlp_utility(model, x_val, y_val)
    args = (stacked, n_k, w_prev, utility, batched, jax.random.key(0))
    sv_s, stats = gtg_shapley_streaming(*args, n_perms=256, use_kernel=True)
    sv_d, _ = gtg_shapley_batched(*args, n_perms=256, use_kernel=True)
    np.testing.assert_allclose(np.asarray(sv_s), np.asarray(sv_d),
                               atol=1e-5)
    sv_exact = exact_shapley(stacked, n_k, w_prev, utility)
    np.testing.assert_allclose(np.asarray(sv_s), np.asarray(sv_exact),
                               atol=0.05)
    assert int(stats.utility_evals) == 256 * m + 2
    # chunking is numerics-invariant on the kernel/ops path too
    for sv_chunk in (1, m, 256 * m):
        sv_c, _ = gtg_shapley_streaming(*args, n_perms=256,
                                        sv_chunk=sv_chunk, use_kernel=True)
        np.testing.assert_array_equal(np.asarray(sv_c), np.asarray(sv_s))

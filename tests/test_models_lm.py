"""Per-arch smoke tests (reduced configs: <=2 layers, d_model<=512,
<=4 experts) + module-level oracles + train/serve consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.core.aggregation import tree_size
from repro.models.lm import model as M
from repro.models.lm.config import ArchConfig, param_count


def _batch(cfg, key, b=2, s=64):
    batch = {"tokens": jax.random.randint(key, (b, s), 0, cfg.vocab)}
    if cfg.frontend == "vision":
        batch["patches"] = jax.random.normal(
            key, (b, cfg.n_frontend_tokens, cfg.d_model))
    if cfg.frontend == "audio":
        batch["frames"] = jax.random.normal(
            key, (b, max(cfg.n_frontend_tokens, 8), cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_forward_and_train_step(arch, key):
    """One forward + one train step on CPU: shapes + no NaNs."""
    cfg = get_config(arch).reduced()
    params = M.init_params(cfg, key)
    assert tree_size(params) == param_count(cfg), "analytic count drift"
    batch = _batch(cfg, key)
    logits, _ = M.forward(cfg, params, batch)
    assert logits.shape == (2, 64, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    opt_init, step = M.make_train_step(cfg)
    p2, _, metrics = jax.jit(step)(params, opt_init(params), batch)
    assert np.isfinite(float(metrics["loss"]))
    # params actually changed
    diff = sum(float(jnp.abs(a - b).sum())
               for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
    assert diff > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_prefill_decode(arch, key):
    cfg = get_config(arch).reduced()
    params = M.init_params(cfg, key)
    batch = _batch(cfg, key)
    cache, logits = M.prefill_step(cfg, params, batch)
    assert logits.shape == (2, cfg.vocab)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    cache2, lg2 = M.decode_step(cfg, params, cache, {"token": tok})
    assert lg2.shape == (2, cfg.vocab)
    assert np.isfinite(np.asarray(lg2, np.float32)).all()
    assert int(cache2["pos"]) == int(cache["pos"]) + 1


@pytest.mark.parametrize("arch", ["tinyllama_1_1b", "mamba2_370m",
                                  "hymba_1_5b", "h2o_danube_3_4b"])
def test_decode_matches_forward(arch, key):
    """Greedy decode logits == full forward logits at the same position."""
    cfg = get_config(arch).reduced()
    cfg = dataclasses.replace(cfg, dtype="float32")
    params = M.init_params(cfg, key)
    s = 32
    tokens = jax.random.randint(key, (1, s + 3), 0, cfg.vocab)
    cache, lg = M.prefill_step(cfg, params, {"tokens": tokens[:, :s]},
                               cache_len=s + 8)
    for i in range(3):
        full_logits, _ = M.forward(cfg, params, {"tokens": tokens[:, : s + i]})
        want = np.asarray(full_logits[:, -1], np.float32)
        got = np.asarray(lg, np.float32)
        np.testing.assert_allclose(got, want, atol=2e-3, rtol=2e-3,
                                   err_msg=f"divergence at decode step {i}")
        cache, lg = M.decode_step(cfg, params, cache,
                                  {"token": tokens[:, s + i]})


def test_ssd_chunked_matches_sequential(key):
    from repro.models.lm.ssm import ssm_forward, ssm_forward_ref, ssm_init
    cfg = get_config("mamba2_370m").reduced()
    cfg = dataclasses.replace(cfg, dtype="float32", ssm_chunk=8)
    p = ssm_init(key, cfg)
    x = jax.random.normal(key, (2, 32, cfg.d_model))
    np.testing.assert_allclose(np.asarray(ssm_forward(p, cfg, x)),
                               np.asarray(ssm_forward_ref(p, cfg, x)),
                               atol=1e-4)


def test_moe_dispatch_matches_dense_ref_at_high_capacity(key):
    from repro.models.lm.moe import moe_apply, moe_apply_ref, moe_init
    cfg = get_config("qwen3_moe_30b_a3b").reduced()
    cfg = dataclasses.replace(cfg, dtype="float32", capacity_factor=8.0)
    p = moe_init(key, cfg)
    x = jax.random.normal(key, (2, 16, cfg.d_model))
    got, aux = moe_apply(p, cfg, x, n_groups=1)
    want = moe_apply_ref(p, cfg, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)
    assert float(aux) >= 1.0 - 1e-5  # load-balance aux lower bound


def test_moe_capacity_drops_tokens(key):
    from repro.models.lm.moe import moe_apply, moe_init
    cfg = get_config("qwen3_moe_30b_a3b").reduced()
    cfg = dataclasses.replace(cfg, dtype="float32", capacity_factor=0.25)
    p = moe_init(key, cfg)
    x = jax.random.normal(key, (2, 32, cfg.d_model))
    got, _ = moe_apply(p, cfg, x, n_groups=1)
    assert np.isfinite(np.asarray(got)).all()


def test_flash_path_matches_dense_path(key):
    """attn_impl flag flips implementation without changing results."""
    cfg = get_config("tinyllama_1_1b").reduced()
    base = dataclasses.replace(cfg, dtype="float32", attn_chunk=32)
    params = M.init_params(base, key)
    batch = {"tokens": jax.random.randint(key, (1, 128), 0, base.vocab)}
    outs = {}
    for impl in ("dense", "flash"):
        c = dataclasses.replace(base, attn_impl=impl)
        outs[impl], _ = M.forward(c, params, batch)
    np.testing.assert_allclose(np.asarray(outs["dense"]),
                               np.asarray(outs["flash"]), atol=2e-3)


def test_scan_vs_unrolled_layers_identical(key):
    cfg = get_config("tinyllama_1_1b").reduced()
    cfg = dataclasses.replace(cfg, dtype="float32")
    params = M.init_params(cfg, key)
    batch = _batch(cfg, key)
    a, _ = M.forward(cfg, params, batch)
    b, _ = M.forward(dataclasses.replace(cfg, scan_layers=False), params, batch)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_rope_partial_fraction_passthrough(key):
    from repro.models.lm.layers import apply_rope
    x = jax.random.normal(key, (1, 8, 2, 64))
    y = apply_rope(x, jnp.arange(8), frac=0.5, theta=1e4)
    # the non-rotary half must pass through unchanged
    np.testing.assert_array_equal(np.asarray(y[..., 32:]),
                                  np.asarray(x[..., 32:]))
    assert not np.allclose(np.asarray(y[..., :32]), np.asarray(x[..., :32]))

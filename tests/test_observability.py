"""DESIGN.md §17 — the analysis tier above the telemetry stream.

Cost cards (per-executable flops/bytes/peak + roofline) on every compile
event, the opt-in profiler capture window, multi-shard JSONL merge
(killed-shard prefixes included), and the bench-regression gate with its
BENCH_trajectory.json ledger.
"""
import io
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.federated.client import ClientConfig
from repro.federated.server import FLConfig, run_federated
from repro.telemetry import (
    SCHEMA_VERSION, Telemetry, TelemetryError, cached_cost_card, cost_card,
    read_events_prefix, trace_capture, validate_events,
)
from repro.telemetry.merge import merge_files, merge_streams
from repro.telemetry.trace import stage

# same shape as tests/test_telemetry.py so the process-wide jitted-run
# caches are warm when the suites run together
TINY = dict(n_clients=8, m=3, rounds=6, n_train=600, n_val=100, n_test=100,
            eval_every=3,
            client=ClientConfig(epochs=2, batches_per_epoch=2, batch_size=16))


# ---- cost cards ----------------------------------------------------------

def test_cost_card_populated_and_cached():
    """The AOT probe unifies flops / memory / roofline into one card, and
    the cache returns the identical object on a warm (fn, avals) key."""
    f = jax.jit(lambda a, b: jnp.tanh(a @ b))
    x = jnp.ones((64, 64), jnp.float32)
    card = cost_card(f, x, x)
    assert card is not None
    assert card["flops"] > 0
    assert card["bytes_accessed"] > 0
    assert card["peak_bytes"] is not None and card["peak_bytes"] > 0
    assert card["intensity_flops_per_byte"] == pytest.approx(
        card["flops"] / card["bytes_accessed"])
    roof = card["roofline"]
    assert roof["dominant"] in ("compute", "memory")
    assert roof["compute_s"] >= 0 and roof["memory_s"] >= 0
    again = cached_cost_card(f, x, x)
    third = cached_cost_card(f, x, x)
    assert again is third                     # dict lookup, no recompile
    assert again.keys() == card.keys()


def test_cost_card_survives_donated_args():
    """The probe lowers on avals, so a buffer consumed by a donating
    dispatch still yields a card afterwards."""
    f = jax.jit(lambda a: a * 2.0, donate_argnums=(0,))
    x = jnp.ones((32,), jnp.float32)
    f(x)                                      # x's buffer is now donated
    card = cost_card(f, x)
    assert card is not None and card["bytes_accessed"] > 0


def test_scan_compile_event_carries_cost_card():
    """The whole-run scan's compile event answers "what does this
    executable cost" without a profiler in the loop."""
    cfg = FLConfig(engine="scan", selector="greedyfed", **TINY)
    tel = Telemetry()
    run_federated(cfg, telemetry=tel)
    validate_events(tel.events)
    [compile_ev] = [e for e in tel.events if e["event"] == "compile"]
    card = compile_ev["cost_card"]
    assert card["flops"] > 0 and card["bytes_accessed"] > 0
    assert card["peak_bytes"] > 0
    assert card["roofline"]["dominant"] in ("compute", "memory")


def test_grid_cost_cards_and_heartbeat_peak(tmp_path):
    """Segmented grid: the per-partition segment_step compile event and
    the aggregate grid_segments event both carry cards, the capture
    window recovers per-stage walls, and the throttled heartbeat surfaces
    the compiled per-device peak next to the ETA."""
    from repro.grid import GridSpec, run_grid

    base = FLConfig(engine="scan", selector="greedyfed",
                    **dict(TINY, rounds=4, eval_every=2))
    gspec = GridSpec.product(base, selectors=["greedyfed"], seeds=[0])
    hb = io.StringIO()
    tel = Telemetry(stream=hb, trace_dir=str(tmp_path / "traces"))
    run_grid(gspec, rounds_per_segment=2, telemetry=tel)
    validate_events(tel.events)

    compiles = {e["program"]: e for e in tel.events
                if e["event"] == "compile"}
    assert set(compiles) == {"segment_step:p0-", "grid_segments"}
    for ev in compiles.values():
        assert ev["cost_card"]["flops"] > 0
        assert ev["cost_card"]["peak_bytes"] > 0

    [prof] = [e for e in tel.events if e["event"] == "profile"]
    assert prof["label"] == "grid"
    assert prof["stage_wall_s"].get("segment", 0) > 0
    assert prof["source"] in ("trace", "host")

    beats = hb.getvalue()
    assert "eta" in beats and "peak" in beats and "MB/dev" in beats


def test_trace_capture_noop_without_trace_dir():
    tel = Telemetry()
    with trace_capture(tel, label="x") as rec:
        assert rec is None
    assert [e for e in tel.events if e["event"] == "profile"] == []


def test_trace_capture_unit(tmp_path):
    """An explicit capture window around a stage()-annotated dispatch
    emits one `profile` event with that stage's wall seconds."""
    tel = Telemetry(trace_dir=str(tmp_path / "tr"))
    x = jnp.ones((128, 128), jnp.float32)
    f = jax.jit(lambda a: a @ a)
    with trace_capture(tel, label="unit"):
        with stage("unit_op"):
            jax.block_until_ready(f(x))
    [prof] = [e for e in tel.events if e["event"] == "profile"]
    assert prof["captured"] in (True, False)
    assert prof["stage_wall_s"]["unit_op"] > 0
    validate_events(tel.events)


# ---- truncated streams ---------------------------------------------------

def _emit_run(tel: Telemetry, run_id: str, rounds: int = 2) -> Telemetry:
    tel.emit("run_start", run_id=run_id, kind="solo")
    for t in range(rounds):
        tel.emit("eval", round=t, test_acc=0.5 + t, val_loss=1.0 - t)
    tel.emit("run_end", wall_time_s=0.1)
    return tel


def test_read_events_prefix_reports_the_cut(tmp_path):
    """A killed run's JSONL tail (half-written record) loads as a
    validating prefix and the cut is reported, never swallowed."""
    path = str(tmp_path / "killed.jsonl")
    with Telemetry(path) as tel:
        _emit_run(tel, "r-dead")
    with open(path, "a") as f:
        f.write('{"v": 1, "seq": 4, "t_s": 9.9, "eve')   # the kill
    events, cut = read_events_prefix(path)
    assert len(events) == 4
    assert validate_events(events) == 4
    assert cut is not None and cut["line"] == 4
    assert cut["raw"].startswith('{"v": 1,')


def test_read_events_prefix_clean_file(tmp_path):
    path = str(tmp_path / "clean.jsonl")
    with Telemetry(path) as tel:
        _emit_run(tel, "r-ok")
    events, cut = read_events_prefix(path)
    assert cut is None and len(events) == 4


# ---- shard merge ---------------------------------------------------------

def test_merge_single_shard_is_identity():
    """K=1 merge adds no shard annotations and renumbers nothing."""
    tel = _emit_run(Telemetry(), "r-solo")
    merged = merge_streams([tel.events])
    assert merged == tel.events
    assert all("shard" not in ev and "src_seq" not in ev for ev in merged)


def test_merge_two_shards_validates_and_preserves_shard_order():
    a = _emit_run(Telemetry(run_id="r-multi"), "r-multi", rounds=3)
    b = _emit_run(Telemetry(run_id="r-multi"), "r-multi", rounds=3)
    merged = merge_streams([a.events, b.events])
    assert len(merged) == len(a.events) + len(b.events)
    assert validate_events(merged) == len(merged)      # shard-scoped rounds
    assert [ev["seq"] for ev in merged] == list(range(len(merged)))
    for i, shard in enumerate((a, b)):
        src = [ev["src_seq"] for ev in merged if ev["shard"] == i]
        assert src == [ev["seq"] for ev in shard.events]  # per-sink order


def test_merge_filters_by_run_id():
    a = _emit_run(Telemetry(), "r-want")
    b = _emit_run(Telemetry(), "r-stray")
    merged = merge_streams([a.events, b.events], run_id="r-want")
    assert merged == a.events                          # stray excluded -> K=1
    with pytest.raises(TelemetryError, match="no shard announces"):
        merge_streams([a.events, b.events], run_id="r-absent")


def test_merge_rejects_invalid_shard():
    a = _emit_run(Telemetry(), "r-bad")
    broken = [dict(ev) for ev in a.events]
    broken[2]["seq"] = 99                              # gap in the chain
    with pytest.raises(TelemetryError, match="shard 0"):
        merge_streams([broken])


def test_merge_files_tolerates_killed_shard(tmp_path):
    pa, pb = str(tmp_path / "a.jsonl"), str(tmp_path / "b.jsonl")
    with Telemetry(pa) as ta:
        _emit_run(ta, "r-files")
    with open(pa, "a") as f:
        f.write('{"trunc')
    with Telemetry(pb) as tb:
        _emit_run(tb, "r-files")
    merged, reports = merge_files([pa, pb])
    assert validate_events(merged) == 8
    assert reports[0]["cut"] is not None and reports[1]["cut"] is None

    from repro.telemetry.merge import main
    out = str(tmp_path / "merged.jsonl")
    assert main([pa, pb, "-o", out]) == 0
    with open(out) as f:
        assert len(f.readlines()) == 8
    assert main([pa, pb, "--strict"]) == 1             # refuse the cut


# ---- report CLI ----------------------------------------------------------

def test_report_json_embeds_schema_version(tmp_path, capsys):
    from repro.telemetry.report import main

    path = str(tmp_path / "ev.jsonl")
    with Telemetry(path) as tel:
        _emit_run(tel, "r-rep")
    assert main([path, "--json", "--validate"]) == 0
    payload = json.loads(capsys.readouterr().out)
    assert payload["schema_version"] == SCHEMA_VERSION
    assert len(payload["rows"]) == 1


def test_report_validate_exits_nonzero_on_malformed(tmp_path, capsys):
    from repro.telemetry.report import main

    path = str(tmp_path / "bad.jsonl")
    with Telemetry(path) as tel:
        _emit_run(tel, "r-bad")
    events, _ = read_events_prefix(path)
    events[1]["seq"] = 7                               # break the chain
    with open(path, "w") as f:
        for ev in events:
            f.write(json.dumps(ev) + "\n")
    assert main([path, "--validate"]) == 1
    assert "validation FAILED" in capsys.readouterr().err


# ---- bench regression gate -----------------------------------------------

def _write_bench(path, off_us: float, host_pct: float = 0.5):
    from repro.telemetry.events import write_bench_json
    write_bench_json(str(path), {
        "schema": "bench_telemetry/v1",
        "e2e_us": {"off": off_us},
        "overhead_pct": {"host": host_pct},
    })


def test_regress_lookup_paths():
    from repro.telemetry.regress import lookup

    obj = {"a": {"b": [10, {"c": 42}]}}
    assert lookup(obj, "a.b[0]") == 10
    assert lookup(obj, "a.b[1].c") == 42
    assert lookup(obj, "a.missing") is None
    assert lookup(obj, "a.b[9]") is None


def test_regress_clean_pass_then_injected_regression(tmp_path):
    """Seeded baselines pass (exit 0, one trajectory entry); a 2x latency
    injection regresses (exit 1); the ledger records both."""
    from repro.telemetry.regress import main

    bench = tmp_path / "bench"
    bench.mkdir()
    baselines = str(tmp_path / "baselines")
    traj = bench / "BENCH_trajectory.json"
    _write_bench(bench / "BENCH_telemetry.json", off_us=1000.0)
    assert main(["--bench-dir", str(bench), "--baselines", baselines,
                 "--seed"]) == 0

    assert main(["--bench-dir", str(bench),
                 "--baselines", baselines]) == 0
    ledger = json.loads(traj.read_text())
    assert ledger["schema"] == "bench_trajectory/v1"
    assert len(ledger["entries"]) == 1
    assert ledger["entries"][0]["status"] == "pass"
    assert ledger["entries"][0]["metrics_regressed"] == 0

    _write_bench(bench / "BENCH_telemetry.json", off_us=2000.0)  # 2x
    assert main(["--bench-dir", str(bench),
                 "--baselines", baselines]) == 1
    ledger = json.loads(traj.read_text())
    assert len(ledger["entries"]) == 2
    assert ledger["entries"][1]["status"] == "regressed"
    recs = ledger["entries"][1]["benches"]["BENCH_telemetry.json"]["metrics"]
    bad = [r for r in recs if r["status"] == "regressed"]
    assert [r["path"] for r in bad] == ["e2e_us.off"]
    assert bad[0]["ratio"] == pytest.approx(2.0)


def test_regress_abs_tol_band(tmp_path):
    """overhead_pct.host is banded in absolute points: 0.5 -> 2.9 stays
    inside the 3-point band, 0.5 -> 4.0 regresses."""
    from repro.telemetry.regress import main

    bench = tmp_path / "bench"
    bench.mkdir()
    baselines = str(tmp_path / "baselines")
    _write_bench(bench / "BENCH_telemetry.json", 1000.0, host_pct=0.5)
    main(["--bench-dir", str(bench), "--baselines", baselines, "--seed"])
    _write_bench(bench / "BENCH_telemetry.json", 1000.0, host_pct=2.9)
    assert main(["--bench-dir", str(bench), "--baselines", baselines,
                 "--trajectory", "none"]) == 0
    _write_bench(bench / "BENCH_telemetry.json", 1000.0, host_pct=4.0)
    assert main(["--bench-dir", str(bench), "--baselines", baselines,
                 "--trajectory", "none"]) == 1


def test_regress_schema_change_is_incomparable_not_fail(tmp_path):
    from repro.telemetry.events import write_bench_json
    from repro.telemetry.regress import run_check

    bench = tmp_path / "bench"
    bench.mkdir()
    baselines = tmp_path / "baselines"
    baselines.mkdir()
    _write_bench(bench / "BENCH_telemetry.json", 1000.0)
    write_bench_json(str(baselines / "BENCH_telemetry.json"),
                     {"schema": "bench_telemetry/v0"})
    entry = run_check(str(bench), str(baselines), None)
    assert entry["status"] == "pass" and entry["metrics_checked"] == 0
    assert any("incomparable" in n for n in entry["notes"])


def test_repo_baselines_are_seeded_and_pass():
    """The committed benchmarks/baselines/ match the committed BENCH
    artifacts (same rev), so the gate passes out of the box."""
    import os

    from repro.telemetry.regress import run_check

    root = os.path.join(os.path.dirname(__file__), "..")
    if not os.path.isdir(os.path.join(root, "benchmarks", "baselines")):
        pytest.skip("baselines not seeded")
    entry = run_check(root, os.path.join(root, "benchmarks", "baselines"),
                      None)                            # no ledger append
    assert entry["status"] == "pass"
    assert entry["metrics_checked"] >= 20

"""Upload-compression codecs: roundtrip fidelity, byte accounting,
end-to-end training, and the selection-vs-compression communication ledger."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.federated.client import ClientConfig
from repro.federated.compression import CODECS, compress_update
from repro.federated.server import FLConfig, run_federated


def _tree(key):
    k1, k2 = jax.random.split(key)
    return {"a": jax.random.normal(k1, (64, 32)),
            "b": {"w": jax.random.normal(k2, (128,))}}


@pytest.mark.parametrize("codec", sorted(CODECS))
def test_codec_roundtrip_and_bytes(codec, key):
    w_ref = _tree(key)
    w_new = jax.tree.map(lambda x: x + 0.01 * jnp.sign(x), w_ref)
    recon, nbytes = (compress_update(codec, w_new, w_ref)
                     if codec != "identity"
                     else (w_new, sum(x.size * 4 for x in jax.tree.leaves(w_new))))
    assert nbytes > 0
    full = sum(int(x.size) * 4 for x in jax.tree.leaves(w_new))
    if codec == "quant8":
        assert nbytes < full / 3.5
    if codec in ("topk", "quant8_topk"):
        assert nbytes < full / 3
    # reconstruction stays close to the true update
    err = max(float(jnp.max(jnp.abs(a - b)))
              for a, b in zip(jax.tree.leaves(recon), jax.tree.leaves(w_new)))
    assert err < 0.05, (codec, err)


def test_quant8_exact_on_symmetric_grid(key):
    # values that are exact integer multiples of scale = max|w|/127 = 0.01
    w_ref = {"w": jnp.zeros(8)}
    w_new = {"w": jnp.asarray([-1.27, -0.63, -0.01, 0.0, 0.01, 0.63, 1.0, 1.27])}
    recon, _ = compress_update("quant8", w_new, w_ref)
    np.testing.assert_allclose(np.asarray(recon["w"]),
                               np.asarray(w_new["w"]), atol=1e-6)


def test_topk_keeps_largest_magnitudes(key):
    w_ref = {"w": jnp.zeros(10)}
    w_new = {"w": jnp.asarray([0., 0., 5., 0., 0., -9., 0., 0., 1., 0.])}
    recon, _ = compress_update("topk", w_new, w_ref)
    r = np.asarray(recon["w"])
    assert r[5] == -9.0  # top-10% of 10 => k=1: the largest survives
    assert np.count_nonzero(r) == 1


FAST = dict(n_clients=6, m=2, rounds=4, n_train=600, n_val=120, n_test=150,
            eval_every=4,
            client=ClientConfig(epochs=2, batches_per_epoch=2, batch_size=16))


def test_compressed_training_and_byte_ledger():
    res_id = run_federated(FLConfig(dataset="mnist", selector="fedavg", **FAST))
    res_q8 = run_federated(FLConfig(dataset="mnist", selector="fedavg",
                                    upload_codec="quant8", **FAST))
    assert np.isfinite(res_q8.final_acc)
    assert res_id.upload_bytes > 0 and res_q8.upload_bytes > 0
    # int8 deltas cut upload ~4x
    assert res_q8.upload_bytes < res_id.upload_bytes / 3
    # downloads (model broadcast) identical
    assert res_q8.download_bytes == res_id.download_bytes

"""Upload-compression codecs: roundtrip fidelity, byte accounting,
end-to-end training, and the selection-vs-compression communication ledger."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                       # offline container
    from _hypothesis_compat import given, settings, st

from repro.federated.client import ClientConfig
from repro.federated.compression import (
    CODECS, FLAT_CODECS, codec_nbytes, codec_roundtrip, compress_update,
    flat_codec_nbytes, flat_codec_roundtrip, flat_roundtrip, flat_sizes,
)
from repro.federated.server import FLConfig, run_federated


def _tree(key):
    k1, k2 = jax.random.split(key)
    return {"a": jax.random.normal(k1, (64, 32)),
            "b": {"w": jax.random.normal(k2, (128,))}}


@pytest.mark.parametrize("codec", sorted(CODECS))
def test_codec_roundtrip_and_bytes(codec, key):
    w_ref = _tree(key)
    w_new = jax.tree.map(lambda x: x + 0.01 * jnp.sign(x), w_ref)
    recon, nbytes = (compress_update(codec, w_new, w_ref)
                     if codec != "identity"
                     else (w_new, sum(x.size * 4 for x in jax.tree.leaves(w_new))))
    assert nbytes > 0
    full = sum(int(x.size) * 4 for x in jax.tree.leaves(w_new))
    if codec == "quant8":
        assert nbytes < full / 3.5
    if codec in ("topk", "quant8_topk"):
        assert nbytes < full / 3
    # reconstruction stays close to the true update
    err = max(float(jnp.max(jnp.abs(a - b)))
              for a, b in zip(jax.tree.leaves(recon), jax.tree.leaves(w_new)))
    assert err < 0.05, (codec, err)


def test_quant8_exact_on_symmetric_grid(key):
    # values that are exact integer multiples of scale = max|w|/127 = 0.01
    w_ref = {"w": jnp.zeros(8)}
    w_new = {"w": jnp.asarray([-1.27, -0.63, -0.01, 0.0, 0.01, 0.63, 1.0, 1.27])}
    recon, _ = compress_update("quant8", w_new, w_ref)
    np.testing.assert_allclose(np.asarray(recon["w"]),
                               np.asarray(w_new["w"]), atol=1e-6)


def test_topk_keeps_largest_magnitudes(key):
    w_ref = {"w": jnp.zeros(10)}
    w_new = {"w": jnp.asarray([0., 0., 5., 0., 0., -9., 0., 0., 1., 0.])}
    recon, _ = compress_update("topk", w_new, w_ref)
    r = np.asarray(recon["w"])
    assert r[5] == -9.0  # top-10% of 10 => k=1: the largest survives
    assert np.count_nonzero(r) == 1


# ------------------------------------------------- flat-vector layer ------
# The §18 flat codecs (one raveled delta vector, static leaf offsets) must
# equal the per-leaf oracle BITWISE when compared in the same lowering
# regime — eager-vs-eager here, because XLA lowers `x / scale` to
# reciprocal-multiply under jit but true division eagerly.

def _odd_tree(key):
    """Ragged leaf sizes (53, 7, 130) incl. injected magnitude ties and an
    all-zero leaf — the top-k tie-break and quant8 zero-guard edge cases."""
    k1, k2 = jax.random.split(key)
    a = jax.random.normal(k1, (53,))
    a = a.at[3].set(a[40]).at[11].set(-a[40])      # exact |.| ties
    return {"a": a, "z": jnp.zeros((7,)),
            "b": {"w": jax.random.normal(k2, (10, 13))}}


@pytest.mark.parametrize("codec", sorted(CODECS))
def test_flat_matches_per_leaf_oracle_bitwise(codec, key):
    w_ref = _odd_tree(key)
    w_new = jax.tree.map(
        lambda x: x + 0.03 * jax.random.normal(
            jax.random.fold_in(key, x.size), x.shape), w_ref)
    got = flat_codec_roundtrip(codec, w_new, w_ref)
    want = codec_roundtrip(codec, w_new, w_ref)
    for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


@pytest.mark.parametrize("codec", sorted(CODECS))
def test_flat_nbytes_matches_oracle(codec, key):
    tree = _odd_tree(key)
    assert flat_codec_nbytes(codec, tree) == codec_nbytes(codec, tree)


@pytest.mark.parametrize("codec", sorted(CODECS))
def test_flat_roundtrip_jits_and_vmaps(codec, key):
    """The flat ops are jittable/vmappable (fixed payload shapes — the
    reason the layer exists); jit equals its own eager run to jit-fusion
    tolerance and vmap over a batch equals the per-row calls bitwise."""
    tree = _odd_tree(key)
    sizes = flat_sizes(tree)
    flat = jnp.concatenate([jnp.ravel(l) for l in jax.tree.leaves(tree)])
    fn = functools.partial(flat_roundtrip, codec, sizes=sizes)
    np.testing.assert_allclose(np.asarray(jax.jit(fn)(flat)),
                               np.asarray(fn(flat)), atol=1e-6)
    batch = jnp.stack([flat, 2.0 * flat, jnp.zeros_like(flat)])
    vm = jax.jit(jax.vmap(fn))(batch)
    one = jax.jit(fn)
    for i in range(batch.shape[0]):
        np.testing.assert_array_equal(np.asarray(vm[i]),
                                      np.asarray(one(batch[i])))


@given(st.integers(min_value=1, max_value=200),
       st.integers(min_value=0, max_value=2**31 - 1))
@settings(max_examples=50, deadline=None)
def test_flat_roundtrip_property(n, seed):
    """Property sweep (runs where hypothesis is installed; skipped by
    tests/_hypothesis_compat.py offline): for random sizes/values every
    codec's flat roundtrip jits, vmaps, and matches the per-leaf oracle."""
    key = jax.random.key(seed)
    tree = {"w": jax.random.normal(key, (n,))}
    w_new = jax.tree.map(lambda x: x * 1.7 + 0.1, tree)
    for codec in sorted(CODECS):
        got = flat_codec_roundtrip(codec, w_new, tree)
        want = codec_roundtrip(codec, w_new, tree)
        np.testing.assert_array_equal(np.asarray(got["w"]),
                                      np.asarray(want["w"]))
        sizes = flat_sizes(tree)
        fn = functools.partial(flat_roundtrip, codec, sizes=sizes)
        flat = jnp.ravel(w_new["w"]) - jnp.ravel(tree["w"])
        np.testing.assert_array_equal(
            np.asarray(jax.jit(jax.vmap(fn))(flat[None])[0]),
            np.asarray(jax.jit(fn)(flat)))


def test_flat_codecs_registry_complete():
    assert set(FLAT_CODECS) == set(CODECS)
    for codec, fc in FLAT_CODECS.items():
        assert callable(fc.encode) and callable(fc.decode)
        assert callable(fc.nbytes)


FAST = dict(n_clients=6, m=2, rounds=4, n_train=600, n_val=120, n_test=150,
            eval_every=4,
            client=ClientConfig(epochs=2, batches_per_epoch=2, batch_size=16))


def test_compressed_training_and_byte_ledger():
    res_id = run_federated(FLConfig(dataset="mnist", selector="fedavg", **FAST))
    res_q8 = run_federated(FLConfig(dataset="mnist", selector="fedavg",
                                    upload_codec="quant8", **FAST))
    assert np.isfinite(res_q8.final_acc)
    assert res_id.upload_bytes > 0 and res_q8.upload_bytes > 0
    # int8 deltas cut upload ~4x
    assert res_q8.upload_bytes < res_id.upload_bytes / 3
    # downloads (model broadcast) identical
    assert res_q8.download_bytes == res_id.download_bytes


@pytest.mark.parametrize("codec", ["identity", "quant8"])
def test_scan_ledger_matches_loop_under_dropout(codec):
    """Byte-ledger parity across engines for a dropout strategy: the scan
    path now charges each round's ACTUAL granted-cohort size (summed from
    the selector's active mask) instead of assuming m, so it must equal
    the loop engine's per-selected-client ledger exactly — under
    greedyfed_dropout AND compression."""
    cfg = dict(FAST, selector="greedyfed_dropout", shapley_max_iters=10,
               upload_codec=codec)
    loop = run_federated(FLConfig(dataset="mnist", engine="loop", **cfg))
    scan = run_federated(FLConfig(dataset="mnist", engine="scan", **cfg))
    assert scan.upload_bytes == loop.upload_bytes
    assert scan.download_bytes == loop.download_bytes
    assert scan.upload_bytes > 0

"""End-to-end FL system behaviour: every selector trains, heterogeneity
mechanisms engage, learning beats the random-init baseline."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.synth import make_dataset
from repro.federated.client import ClientConfig
from repro.federated.server import FLConfig, run_centralized, run_federated

FAST = dict(n_clients=8, m=2, rounds=6, n_train=800, n_val=150, n_test=200,
            eval_every=3,
            client=ClientConfig(epochs=2, batches_per_epoch=2, batch_size=16))


@pytest.mark.parametrize("selector", ["greedyfed", "fedavg", "ucb",
                                      "s_fedavg", "power_of_choice",
                                      "fedprox"])
def test_selector_end_to_end(selector):
    kw = dict(FAST)
    if selector == "fedprox":
        kw["client"] = kw["client"]._replace(prox_mu=0.1)
    res = run_federated(FLConfig(dataset="mnist", selector=selector, **kw))
    assert res.final_acc > 0.2, f"{selector} failed to learn: {res.final_acc}"
    assert len(res.selections) == FAST["rounds"]
    assert all(len(s) == FAST["m"] for s in res.selections)


def test_greedyfed_shapley_values_populated():
    res = run_federated(FLConfig(dataset="mnist", selector="greedyfed", **FAST))
    assert res.shapley_evals > 0
    assert np.isfinite(res.sv_final).all()
    # RR phase guarantees every client was selected at least once
    assert (res.selection_counts >= 1).all()


def test_straggler_and_privacy_paths():
    cfg = FLConfig(dataset="mnist", selector="greedyfed",
                   straggler_frac=0.5, privacy_sigma=0.05, **FAST)
    res = run_federated(cfg)
    assert np.isfinite(res.final_acc)


def test_noise_hurts_accuracy():
    accs = {}
    for sigma in (0.0, 0.5):
        kw = dict(FAST, rounds=8)
        cfg = FLConfig(dataset="mnist", selector="fedavg",
                       privacy_sigma=sigma, seed=3, **kw)
        accs[sigma] = run_federated(cfg).final_acc
    assert accs[0.5] < accs[0.0] + 0.05, accs


def test_centralized_upper_bound_runs():
    res = run_centralized(FLConfig(dataset="mnist", **FAST))
    assert res.final_acc > 0.3


def test_exponential_sv_averaging_variant():
    cfg = FLConfig(dataset="mnist", selector="greedyfed",
                   sv_averaging="exponential", sv_alpha=0.5, **FAST)
    res = run_federated(cfg)
    assert np.isfinite(res.final_acc)


def test_shared_dataset_consistency_across_selectors():
    data = make_dataset("mnist", n_train=800, n_val=150, n_test=200, seed=7)
    r1 = run_federated(FLConfig(dataset="mnist", selector="fedavg", **FAST), data=data)
    r2 = run_federated(FLConfig(dataset="mnist", selector="fedavg", **FAST), data=data)
    assert r1.final_acc == r2.final_acc, "same seed+data must reproduce"

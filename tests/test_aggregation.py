"""ModelAverage properties (hypothesis): convexity, normalisation, masking."""
import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                       # offline container
    from _hypothesis_compat import given, settings, st

from repro.core.aggregation import (
    normalized_weights, subset_average, tree_stack, weighted_average,
)


@settings(max_examples=25, deadline=None)
@given(m=st.integers(1, 8), seed=st.integers(0, 100))
def test_weights_normalised_and_masked(m, seed):
    rng = np.random.default_rng(seed)
    n_k = jnp.asarray(rng.integers(1, 100, m).astype(np.float32))
    mask = jnp.asarray(rng.integers(0, 2, m).astype(np.float32))
    w = normalized_weights(n_k, mask)
    if float(mask.sum()) > 0:
        np.testing.assert_allclose(float(w.sum()), 1.0, rtol=1e-5)
        assert np.all(np.asarray(w)[np.asarray(mask) == 0] == 0.0)
    else:
        assert np.all(np.asarray(w) == 0.0)


@settings(max_examples=25, deadline=None)
@given(m=st.integers(2, 6), seed=st.integers(0, 100))
def test_average_is_convex_combination(m, seed):
    """Averaged params lie inside the convex hull (per coordinate)."""
    models = [{"a": jax.random.normal(jax.random.key(seed + i), (4, 3))}
              for i in range(m)]
    stacked = tree_stack(models)
    n_k = jnp.arange(1.0, m + 1.0)
    avg = weighted_average(stacked, normalized_weights(n_k))
    arr = np.stack([np.asarray(mm["a"]) for mm in models])
    assert np.all(np.asarray(avg["a"]) <= arr.max(0) + 1e-5)
    assert np.all(np.asarray(avg["a"]) >= arr.min(0) - 1e-5)


def test_singleton_subset_returns_that_model():
    models = [{"a": jnp.ones(3) * i} for i in range(4)]
    stacked = tree_stack(models)
    n_k = jnp.array([1.0, 2.0, 3.0, 4.0])
    mask = jnp.array([0.0, 0.0, 1.0, 0.0])
    out = subset_average(stacked, n_k, mask)
    np.testing.assert_allclose(np.asarray(out["a"]), 2.0)

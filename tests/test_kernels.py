"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ce_loss.kernel import ce_loss_kernel
from repro.kernels.ce_loss.ops import ce_loss
from repro.kernels.ce_loss.ref import ce_loss_ref
from repro.kernels.cohort_gather.kernel import cohort_gather_kernel
from repro.kernels.cohort_gather.ops import cohort_gather, cohort_take
from repro.kernels.cohort_gather.ref import cohort_gather_ref
from repro.kernels.delta_codec.kernel import LANES, delta_codec_kernel
from repro.kernels.delta_codec.ops import delta_codec_roundtrip
from repro.kernels.delta_codec.ref import delta_codec_ref
from repro.kernels.flash_attention.ops import flash_attention_tpu
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.prefix_avg.kernel import prefix_avg_kernel
from repro.kernels.prefix_avg.ops import prefix_avg
from repro.kernels.prefix_avg.ref import prefix_avg_ref
from repro.kernels.weighted_avg.kernel import weighted_avg_kernel
from repro.kernels.weighted_avg.ops import weighted_avg
from repro.kernels.weighted_avg.ref import weighted_avg_ref
from repro.models.lm.attention import dense_attention


def _perms(key, r, m):
    return jnp.stack([jax.random.permutation(jax.random.fold_in(key, i), m)
                      for i in range(r)])


# ------------------------------------------------------- weighted_avg ------
@pytest.mark.parametrize("m,d,r", [(2, 2048, 4), (5, 4096, 3), (8, 6144, 16),
                                   (20, 2048, 50)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_weighted_avg_kernel_matches_ref(m, d, r, dtype, key):
    stacked = jax.random.normal(key, (m, d), dtype)
    w = jax.random.dirichlet(key, jnp.ones(m), (r,)).astype(dtype)
    got = weighted_avg_kernel(stacked, w, block_d=2048, interpret=True)
    want = weighted_avg_ref(stacked, w)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=2e-2 if dtype == jnp.bfloat16 else 1e-5)


def test_weighted_avg_tree_wrapper_pads_ragged_leaves(key):
    tree = {"a": jax.random.normal(key, (4, 100, 33)),
            "b": jax.random.normal(key, (4, 5000))}
    w = jax.random.dirichlet(key, jnp.ones(4), (6,))
    got = weighted_avg(tree, w, use_kernel=True, interpret=True)
    for name, leaf in tree.items():
        want = jnp.einsum("rm,m...->r...", w, leaf)
        np.testing.assert_allclose(np.asarray(got[name]), np.asarray(want),
                                   atol=1e-4)


def test_weighted_avg_subset_masks_recover_members(key):
    """One-hot weight rows must return the individual client models."""
    stacked = jax.random.normal(key, (4, 4096))
    w = jnp.eye(4)
    got = weighted_avg_kernel(stacked, w, block_d=2048, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(stacked), atol=1e-6)


# -------------------------------------------------------- prefix_avg ------
@pytest.mark.parametrize("m,d,r", [(3, 2048, 4), (5, 4096, 7),
                                   (8, 2048, 16), (20, 2048, 11)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_prefix_avg_kernel_matches_ref(m, d, r, dtype, key):
    stacked = jax.random.normal(key, (m, d), dtype)
    n_k = jnp.arange(1.0, m + 1.0) * 10
    perms = _perms(key, r, m)
    got = prefix_avg_kernel(stacked, perms, n_k, block_d=2048,
                            interpret=True)
    want = prefix_avg_ref(stacked, perms, n_k)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=2e-2 if dtype == jnp.bfloat16 else 1e-5)


def test_prefix_avg_matches_dense_prefix_weights(key):
    """The running-sum walk equals the dense prefix-weight contraction —
    the §8 oracle the streaming estimator replaces."""
    from repro.core.shapley_batched import prefix_weight_matrix

    m, d, r = 6, 512, 5
    stacked = jax.random.normal(key, (m, d))
    n_k = jnp.arange(1.0, m + 1.0) * 7
    perms = _perms(key, r, m)
    got = prefix_avg_ref(stacked, perms, n_k)
    w = prefix_weight_matrix(perms, n_k).reshape(r * m, m)
    want = weighted_avg_ref(stacked, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_prefix_avg_tree_wrapper_pads_ragged_leaves(key):
    """Non-divisible D: big leaves are padded to the kernel tile and
    sliced back; small leaves route to the jnp reference."""
    from repro.core.shapley_batched import prefix_weight_matrix

    m, r = 4, 6
    tree = {"a": jax.random.normal(key, (m, 100, 33)),
            "b": jax.random.normal(key, (m, 5000))}
    n_k = jnp.array([5.0, 10.0, 15.0, 20.0])
    perms = _perms(key, r, m)
    got = prefix_avg(tree, perms, n_k, use_kernel=True, interpret=True)
    w = prefix_weight_matrix(perms, n_k).reshape(r * m, m)
    for name, leaf in tree.items():
        want = jnp.einsum("rm,m...->r...", w, leaf)
        assert got[name].shape == (r * m,) + leaf.shape[1:]
        np.testing.assert_allclose(np.asarray(got[name]), np.asarray(want),
                                   atol=1e-4)


def test_prefix_avg_identity_walk_recovers_running_average(key):
    """First position of every walk must be exactly that client's model."""
    m, d = 4, 2048
    stacked = jax.random.normal(key, (m, d))
    n_k = jnp.ones((m,))
    perms = jnp.stack([jnp.roll(jnp.arange(m), -i) for i in range(m)])
    got = prefix_avg_kernel(stacked, perms, n_k, block_d=2048,
                            interpret=True).reshape(m, m, d)
    for i in range(m):
        np.testing.assert_allclose(np.asarray(got[i, 0]),
                                   np.asarray(stacked[i]), atol=1e-6)


# ------------------------------------------------------------ ce_loss ------
@pytest.mark.parametrize("r,v", [(4, 2048), (16, 4096), (8, 10240)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ce_loss_kernel_matches_ref(r, v, dtype, key):
    logits = jax.random.normal(key, (r, v), dtype) * 4
    labels = jax.random.randint(key, (r,), 0, v)
    got = ce_loss_kernel(logits, labels, block_v=2048, interpret=True)
    want = ce_loss_ref(logits, labels)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-2 if dtype == jnp.bfloat16 else 1e-5)


def test_ce_loss_wrapper_handles_unaligned_vocab(key):
    logits = jax.random.normal(key, (6, 5001))
    labels = jax.random.randint(key, (6,), 0, 5001)
    got = ce_loss(logits, labels, use_kernel=True, interpret=True)
    want = jnp.mean(ce_loss_ref(logits, labels))
    np.testing.assert_allclose(float(got), float(want), rtol=1e-5)


# ------------------------------------------------------ cohort_gather ------
# A gather copies bits, so every comparison below is exact equality —
# including bf16 and repeated/boundary ids.
@pytest.mark.parametrize("n,d,m", [(7, 2048, 3), (16, 4096, 5),
                                   (100, 2048, 20), (33, 6144, 8)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_cohort_gather_kernel_matches_ref(n, d, m, dtype, key):
    table = jax.random.normal(key, (n, d), dtype)
    ids = jax.random.randint(key, (m,), 0, n)
    got = cohort_gather_kernel(table, ids, block_d=2048, interpret=True)
    want = cohort_gather_ref(table, ids)
    assert got.dtype == table.dtype
    np.testing.assert_array_equal(np.asarray(got, np.float32),
                                  np.asarray(want, np.float32))


def test_cohort_gather_kernel_repeated_and_boundary_ids(key):
    n, d = 9, 2048
    table = jax.random.normal(key, (n, d))
    ids = jnp.array([0, n - 1, 3, 3, 0], jnp.int32)
    got = cohort_gather_kernel(table, ids, block_d=2048, interpret=True)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(table)[np.asarray(ids)])


def test_cohort_take_pads_unaligned_feature_dim(key):
    """Non-divisible flattened D: padded to the kernel tile, sliced back,
    still bit-exact against jnp.take."""
    table = jax.random.normal(key, (11, 37, 95))    # 37*95 = 3515
    ids = jnp.array([10, 0, 4], jnp.int32)
    got = cohort_take(table, ids, use_kernel=True, interpret=True)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(table)[np.asarray(ids)])


def test_cohort_take_integer_table(key):
    table = jax.random.randint(key, (13, 2048), -1000, 1000, jnp.int32)
    ids = jnp.array([12, 12, 1, 0], jnp.int32)
    got = cohort_take(table, ids, use_kernel=True, interpret=True)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(table)[np.asarray(ids)])


def test_cohort_gather_tree_wrapper_ragged_leaves(key):
    """Pytree wrapper: ragged leaves (incl. a 1-D per-client vector) all
    gathered along axis 0, each bit-identical to jnp.take."""
    tree = {"a": jax.random.normal(key, (10, 100, 33)),
            "b": jax.random.normal(key, (10, 5000)),
            "nv": jax.random.randint(key, (10,), 0, 64, jnp.int32)}
    ids = jnp.array([9, 2, 2, 0, 7], jnp.int32)
    got = cohort_gather(tree, ids, use_kernel=True, interpret=True)
    for name, leaf in tree.items():
        assert got[name].shape == (5,) + leaf.shape[1:]
        np.testing.assert_array_equal(np.asarray(got[name]),
                                      np.asarray(leaf)[np.asarray(ids)])


# -------------------------------------------------------- delta_codec ------
# The fused upload-codec roundtrip (DESIGN.md §18).  Parity is BITWISE
# against the jnp rowwise oracle — quantisation grids and the exact
# (sort-free) top-k must agree bit for bit, so compression error in an
# engine run is attributable to the codec's math, never to the kernel.
# Comparisons jit the ref: XLA lowers `x / scale` to reciprocal-multiply
# under jit but true division eagerly, so eager-vs-jit differs by design.
_jit_ref = jax.jit(functools.partial(delta_codec_ref),
                   static_argnames=("codec", "k"))


def _pad_lanes(x):
    d = x.shape[-1]
    pad = (-d) % LANES
    return jnp.pad(x, ((0, 0), (0, pad))) if pad else x


@pytest.mark.parametrize("m,d", [(3, 128), (4, 640), (2, 1000), (5, 4096),
                                 (1, 130)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("codec", ["quant8", "topk", "quant8_topk"])
def test_delta_codec_kernel_matches_ref(m, d, codec, dtype, key):
    """4+ shapes (incl. non-LANES-divisible D: 1000, 130) x 2 dtypes:
    the single-pass kernel equals the jitted rowwise oracle bitwise."""
    x = (jax.random.normal(key, (m, d)) * 3).astype(dtype)
    k = max(1, d // 10)
    got = delta_codec_kernel(_pad_lanes(x), codec=codec, k=k, d_true=d,
                             interpret=True)[:, :d]
    want = _jit_ref(x, codec=codec, k=k)
    assert got.dtype == x.dtype
    np.testing.assert_array_equal(np.asarray(got, np.float32),
                                  np.asarray(want, np.float32),
                                  err_msg=f"{codec} {m}x{d} {dtype}")
    # padding lanes must not leak into the kept set or the quant scale
    np.testing.assert_array_equal(
        np.asarray(delta_codec_kernel(_pad_lanes(x), codec=codec, k=k,
                                      d_true=d, interpret=True)[:, d:]),
        0.0)


def test_delta_codec_topk_tie_semantics(key):
    """Injected magnitude ties resolve lowest-index-first — the lax.top_k
    contract the per-leaf oracle inherits; exact count always == k."""
    d = 256
    x = jnp.zeros((2, d)).at[:, [3, 7, 100, 200]].set(
        jnp.asarray([[2.0, -2.0, 2.0, 1.0], [-5.0, 5.0, 5.0, 5.0]]))
    for k in (1, 2, 3):
        got = delta_codec_kernel(x, codec="topk", k=k, d_true=d,
                                 interpret=True)
        want = _jit_ref(x, codec="topk", k=k)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        assert int(jnp.count_nonzero(got[1])) == k


def test_delta_codec_zero_rows(key):
    """All-zero rows: quant8 must not divide by zero; top-k keeps k
    (zero-valued) slots, matching lax.top_k on a constant vector."""
    x = jnp.zeros((3, 512))
    for codec in ("quant8", "topk", "quant8_topk"):
        got = delta_codec_kernel(x, codec=codec, k=8, d_true=512,
                                 interpret=True)
        assert np.isfinite(np.asarray(got)).all()
        np.testing.assert_array_equal(np.asarray(got), 0.0)


def test_delta_codec_ops_matches_legacy_tree_map(key):
    """The pytree wrapper (what round_engine now calls) reproduces the
    legacy per-leaf chain `vmap(codec_roundtrip)` it replaced, at ragged
    MLP-like shapes — both jitted, same lowering regime."""
    from repro.federated.compression import codec_roundtrip

    params = {"w1": jax.random.normal(key, (784, 32)) * 0.1,
              "b1": jnp.zeros((32,)),
              "w2": jax.random.normal(key, (32, 10)) * 0.3}
    stacked = jax.tree.map(
        lambda p: p[None] + 0.01 * jax.random.normal(
            jax.random.fold_in(key, p.ndim), (4,) + p.shape), params)
    for codec in ("quant8", "topk", "quant8_topk"):
        got = delta_codec_roundtrip(stacked, params, codec)
        legacy = jax.jit(lambda s, p, c=codec: jax.vmap(
            lambda w: codec_roundtrip(c, w, p))(s))(stacked, params)
        for name in params:
            np.testing.assert_allclose(
                np.asarray(got[name]), np.asarray(legacy[name]),
                atol=1e-6, err_msg=f"{codec} {name}")


def test_delta_codec_ops_kernel_path_matches_ref_path(key):
    """use_kernel=True (interpret) and the fused-ref fallback agree
    through the jitted wrapper to jit-fusion tolerance (the ref branch
    FMA-fuses the trailing `ref + rt` add; the kernel boundary blocks
    that fusion — one-ulp shifts, the repo-wide parity contract), and
    the size gate keeps the small 32-wide leaf on the ref path in both:
    that leaf must stay bitwise."""
    params = {"big": jax.random.normal(key, (64, 48)),   # d=3072: kernel
              "small": jax.random.normal(key, (32,))}    # d=32: ref
    stacked = jax.tree.map(
        lambda p: p[None] + 0.05 * jax.random.normal(
            jax.random.fold_in(key, p.size), (3,) + p.shape), params)
    for codec in ("quant8", "topk", "quant8_topk"):
        a = delta_codec_roundtrip(stacked, params, codec,
                                  use_kernel=True, interpret=True)
        b = delta_codec_roundtrip(stacked, params, codec,
                                  use_kernel=False, interpret=True)
        np.testing.assert_array_equal(np.asarray(a["small"]),
                                      np.asarray(b["small"]),
                                      err_msg=f"{codec} small")
        np.testing.assert_allclose(np.asarray(a["big"]),
                                   np.asarray(b["big"]),
                                   atol=1e-6, err_msg=f"{codec} big")


def test_delta_codec_identity_passthrough(key):
    stacked = {"w": jax.random.normal(key, (2, 100, 33))}
    out = delta_codec_roundtrip(stacked, {"w": jnp.zeros((100, 33))},
                                "identity")
    np.testing.assert_array_equal(np.asarray(out["w"]),
                                  np.asarray(stacked["w"]))


# ---------------------------------------------------- flash_attention ------
@pytest.mark.parametrize("b,s,hq,kh,hd,win", [
    (2, 256, 4, 2, 64, 0),
    (1, 512, 8, 8, 32, 128),
    (2, 256, 6, 2, 64, 64),
    (1, 256, 2, 1, 128, 0),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_dense(b, s, hq, kh, hd, win, dtype, key):
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (b, s, hq, hd), dtype)
    k = jax.random.normal(k2, (b, s, kh, hd), dtype)
    v = jax.random.normal(k3, (b, s, kh, hd), dtype)
    got = flash_attention_tpu(q, k, v, causal=True, window=win,
                              block_q=128, block_k=128, interpret=True)
    want = dense_attention(q, k, v, q_pos=jnp.arange(s), kv_pos=jnp.arange(s),
                           causal=True, window=win)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        atol=3e-2 if dtype == jnp.bfloat16 else 2e-5)


def test_flash_kernel_vs_kernel_ref(key):
    """ops-level oracle (attention_ref) agrees with model-level dense."""
    q = jax.random.normal(key, (3, 128, 64))
    k = jax.random.normal(key, (3, 128, 64))
    v = jax.random.normal(key, (3, 128, 64))
    a = attention_ref(q, k, v, causal=True)
    b2 = dense_attention(q[:, :, None], k[:, :, None], v[:, :, None],
                         q_pos=jnp.arange(128), kv_pos=jnp.arange(128),
                         causal=True)[:, :, 0]
    np.testing.assert_allclose(np.asarray(a), np.asarray(b2), atol=1e-5)

"""Round/run engines: batched parity with the legacy loop (the oracle),
the whole-run scan engine's parity with batched, the virtual-clock
scheduler, and multi-seed / multi-strategy replication."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.engine.schedule import (
    ClientClock, ScheduleConfig, deadline_epochs, make_client_clock,
    round_duration_s,
)
from repro.federated.client import ClientConfig
from repro.federated.server import (
    FLConfig, run_federated, run_federated_replicated, setup_run,
)

TINY = dict(n_clients=8, m=3, rounds=6, n_train=600, n_val=100, n_test=100,
            eval_every=3,
            client=ClientConfig(epochs=2, batches_per_epoch=2, batch_size=16))


def _flat(params):
    return np.concatenate([np.ravel(np.asarray(l))
                           for l in jax.tree.leaves(params)])


def _assert_parity(a, b, atol=1e-5):
    assert len(a.selections) == len(b.selections)
    for t, (sa, sb) in enumerate(zip(a.selections, b.selections)):
        np.testing.assert_array_equal(sa, sb, err_msg=f"round {t}")
    np.testing.assert_allclose(_flat(a.params), _flat(b.params), atol=atol)
    assert a.upload_bytes == b.upload_bytes
    assert a.download_bytes == b.download_bytes
    assert a.shapley_evals == b.shapley_evals


@pytest.mark.parametrize("selector", ["greedyfed", "fedavg",
                                      "power_of_choice"])
def test_batched_engine_matches_loop(selector):
    """Same selections, final params, and byte accounting for all three
    strategy families (SV-driven, random, loss-driven)."""
    cfg = dict(TINY, selector=selector, straggler_frac=0.25,
               privacy_sigma=0.05)
    loop = run_federated(FLConfig(engine="loop", **cfg))
    fused = run_federated(FLConfig(engine="batched", **cfg))
    _assert_parity(loop, fused)
    assert fused.dispatches < loop.dispatches  # the point of the engine


def test_batched_engine_matches_loop_with_codec():
    """The upload codec runs inside the fused trace; accounting and lossy
    reconstruction must match the loop's per-client host path."""
    cfg = dict(TINY, selector="fedavg", upload_codec="quant8")
    loop = run_federated(FLConfig(engine="loop", **cfg))
    fused = run_federated(FLConfig(engine="batched", **cfg))
    # fused-multiply-add differences can flip a value across a quantisation
    # bin boundary; one int8 bin of a ~1e-2 delta is ~1e-4
    _assert_parity(loop, fused, atol=5e-4)
    assert loop.upload_bytes < loop.download_bytes  # quant8 actually shrank


def test_unknown_engine_rejected():
    with pytest.raises(ValueError, match="engine"):
        run_federated(FLConfig(engine="warp", **TINY))


# -------------------------------------------------------------------- scan --
@pytest.mark.parametrize("selector", ["greedyfed", "fedavg",
                                      "power_of_choice"])
def test_scan_engine_matches_batched(selector):
    """The whole-run lax.scan path reproduces the batched engine —
    selections bit-identical, params/bytes/eval counts matching — while
    issuing ONE train dispatch for the run instead of one per round."""
    cfg = dict(TINY, selector=selector, privacy_sigma=0.05)
    batched = run_federated(FLConfig(engine="batched", **cfg))
    scan = run_federated(FLConfig(engine="scan", **cfg))
    _assert_parity(batched, scan)
    assert scan.dispatches == 1
    assert batched.dispatches >= TINY["rounds"]
    # in-scan cadenced eval reproduces the host-side eval history
    assert [t for t, _ in scan.test_acc] == [t for t, _ in batched.test_acc]
    np.testing.assert_allclose([a for _, a in scan.test_acc],
                               [a for _, a in batched.test_acc], atol=1e-5)


def test_scan_engine_matches_batched_with_codec():
    cfg = dict(TINY, selector="fedavg", upload_codec="quant8")
    batched = run_federated(FLConfig(engine="batched", **cfg))
    scan = run_federated(FLConfig(engine="scan", **cfg))
    _assert_parity(batched, scan, atol=5e-4)
    assert scan.upload_bytes < scan.download_bytes


def test_scan_engine_schedule_parity():
    """Deadline-derived E_k is deterministic, so the scan engine matches
    batched under a virtual clock — including simulated time."""
    cfg = dict(TINY, selector="fedavg",
               schedule=ScheduleConfig(deadline_s=100.0))
    batched = run_federated(FLConfig(engine="batched", **cfg))
    scan = run_federated(FLConfig(engine="scan", **cfg))
    _assert_parity(batched, scan)
    assert scan.sim_time_s == pytest.approx(batched.sim_time_s)
    assert scan.sim_time_s > 0


def test_scan_engine_random_stragglers():
    """straggler_frac uses a pre-drawn (T, N) table on the scan path —
    distribution-identical to the legacy stream, not bit-identical — so
    the run must still train and grant reduced budgets."""
    cfg = dict(TINY, selector="fedavg", straggler_frac=0.5)
    r = run_federated(FLConfig(engine="scan", **cfg))
    flat = _flat(r.params)
    assert np.isfinite(flat).all()
    assert len(r.selections) == TINY["rounds"]
    assert r.dispatches == 1


def test_device_selected_round_fuses_selection():
    """sim.device_selected_round: select → gather → train → aggregate in
    one jitted program, with selection counts bumped on-device."""
    from repro.core.selection_jax import DeviceSelectionContext
    from repro.federated.sim import device_selected_round

    cfg = FLConfig(selector="fedavg", **TINY)
    s = setup_run(cfg)
    spec, state = s.sel_spec, s.sel_state
    ctx = DeviceSelectionContext(
        data_fractions=jnp.asarray(s.fractions),
        local_losses=jnp.zeros(cfg.n_clients, jnp.float32),
        poc_d=jnp.asarray(0, jnp.int32))
    epochs_all = jnp.full((cfg.n_clients,), cfg.client.epochs, jnp.int32)
    sel, state, new_params = device_selected_round(
        s.model, cfg.client, spec, s.params, s.xs, s.ys, s.n_valid,
        jnp.asarray(s.sigma_k_all), epochs_all, state, ctx,
        jax.random.key(3))
    assert sel.shape == (cfg.m,)
    assert len(set(int(i) for i in sel)) == cfg.m
    assert int(state.round) == 1
    assert int(np.asarray(state.valuation.counts).sum()) == cfg.m
    assert np.isfinite(_flat(new_params)).all()
    assert not np.allclose(_flat(new_params), _flat(s.params))


# ---------------------------------------------------------------- schedule --
def test_eval_mask_table():
    """schedule.eval_mask is THE eval-cadence definition: cadence multiples
    plus the final round, deduped — eval_every > rounds still yields
    exactly one eval (the final round)."""
    from repro.engine.schedule import eval_mask

    np.testing.assert_array_equal(
        eval_mask(6, 3), [False, False, True, False, False, True])
    # final round always evals, even off-cadence
    np.testing.assert_array_equal(
        eval_mask(5, 3), [False, False, True, False, True])
    # the t == rounds-1 special case is deduped with the cadence hit
    assert eval_mask(6, 2).sum() == 3
    # eval_every > rounds: exactly one eval, at the end
    m = eval_mask(6, 100)
    assert m.sum() == 1 and m[-1]
    assert eval_mask(0, 5).shape == (0,)
    with pytest.raises(ValueError, match="eval_every"):
        eval_mask(6, 0)


def test_eval_every_beyond_rounds_single_eval_end_to_end():
    """Both host-driven and scan engines honour the single final eval when
    eval_every exceeds the round budget."""
    cfg = dict(TINY, selector="fedavg")
    cfg["eval_every"] = 1000
    loop = run_federated(FLConfig(engine="loop", **cfg))
    scan = run_federated(FLConfig(engine="scan", **cfg))
    for r in (loop, scan):
        assert [t for t, _ in r.test_acc] == [TINY["rounds"]]
    np.testing.assert_allclose(loop.test_acc[0][1], scan.test_acc[0][1],
                               atol=1e-5)


def test_deadline_epochs_derivation():
    clock = ClientClock(epoch_time_s=np.array([0.1, 0.2, 1.0, 0.1]),
                        comm_time_s=np.array([0.05, 0.05, 0.05, 2.0]))
    scfg = ScheduleConfig(deadline_s=0.5)
    e = deadline_epochs(clock, scfg, np.arange(4), max_epochs=3)
    # budgets: 0.45/0.1=4 (clip 3), 0.45/0.2=2, 0.45/1.0=0, comm alone > tau
    np.testing.assert_array_equal(e, [3, 2, 0, 0])
    # duration: slowest completer, each capped at the deadline
    d = round_duration_s(clock, scfg, np.arange(4), e)
    assert d == pytest.approx(0.5)  # client 3's transfer overruns -> tau
    d2 = round_duration_s(clock, scfg, np.array([0]), np.array([3]))
    assert d2 == pytest.approx(0.05 + 3 * 0.1)


def test_make_client_clock_shapes_and_scaling():
    rng = np.random.default_rng(0)
    scfg = ScheduleConfig(epoch_time_mean_s=0.2, data_scaled=True)
    n_k = np.array([10.0, 10.0, 1000.0, 10.0])
    clock = make_client_clock(scfg, 4, model_bytes=10**6, rng=rng, n_k=n_k)
    assert clock.epoch_time_s.shape == (4,) and clock.comm_time_s.shape == (4,)
    assert (clock.epoch_time_s > 0).all() and (clock.comm_time_s > 0).all()
    # the data-heavy client is slower than the light ones on average
    assert clock.epoch_time_s[2] > clock.epoch_time_s[[0, 1, 3]].mean()


def test_schedule_deadline_gates_training():
    """A generous deadline trains normally; an impossible one yields zero
    local epochs (accuracy stays near chance) — time-derived stragglers."""
    loose = run_federated(FLConfig(
        selector="fedavg", engine="batched",
        schedule=ScheduleConfig(deadline_s=100.0), **TINY))
    tight = run_federated(FLConfig(
        selector="fedavg", engine="batched",
        schedule=ScheduleConfig(deadline_s=1e-4), **TINY))
    assert loose.sim_time_s > 0 and tight.sim_time_s > 0
    assert tight.sim_time_s < loose.sim_time_s
    assert loose.final_acc > 0.5
    assert tight.final_acc < 0.35  # no client ever finishes an epoch
    # both engines accept the schedule and agree
    loop = run_federated(FLConfig(
        selector="fedavg", engine="loop",
        schedule=ScheduleConfig(deadline_s=100.0), **TINY))
    _assert_parity(loop, loose)


# -------------------------------------------------------------- replicated --
def test_replicated_matches_solo_runs():
    """Each replica of the vmapped multi-seed run reproduces the solo
    batched run at its seed: selections, params, accounting."""
    cfg = FLConfig(selector="fedavg", engine="batched", **TINY)
    seeds = [0, 1]
    reps = run_federated_replicated(cfg, seeds)
    assert len(reps) == len(seeds)
    for s, rep in zip(seeds, reps):
        solo = run_federated(dataclasses.replace(cfg, seed=s))
        _assert_parity(solo, rep)
        assert rep.config.seed == s


def test_replicated_shapley_selector():
    """GTG-Shapley (while_loop + cond) composes with the seed vmap."""
    cfg = FLConfig(selector="greedyfed", engine="batched",
                   shapley_max_iters=10, **TINY)
    reps = run_federated_replicated(cfg, seeds=[0, 2])
    for rep in reps:
        assert np.isfinite(_flat(rep.params)).all()
        assert rep.shapley_evals > 0
        assert len(rep.selections) == TINY["rounds"]
    # replicas genuinely differ (different partitions/keys)
    assert not np.allclose(_flat(reps[0].params), _flat(reps[1].params))


def test_replicated_scan_matches_solo_runs():
    """cfg.engine='scan' replication vmaps the WHOLE run — selector state
    included — and each replica reproduces the solo scan run at its seed."""
    cfg = FLConfig(selector="fedavg", engine="scan", **TINY)
    seeds = [0, 1]
    reps = run_federated_replicated(cfg, seeds)
    assert len(reps) == len(seeds)
    for s, rep in zip(seeds, reps):
        solo = run_federated(dataclasses.replace(cfg, seed=s))
        _assert_parity(solo, rep)
        assert rep.config.seed == s
        assert rep.dispatches == 1


def test_replicated_scan_mixed_strategy_grid():
    """A strategies × seeds grid lax.switch-dispatches through ONE compiled
    program; every cell reproduces its solo scan run (SV superset: non-SV
    replicas just report zero shapley evals)."""
    cfg = FLConfig(selector="greedyfed", engine="scan",
                   shapley_max_iters=10, **TINY)
    grid = run_federated_replicated(cfg, [0], selectors=["greedyfed",
                                                         "fedavg"])
    assert [r.config.selector for r in grid] == ["greedyfed", "fedavg"]
    for r in grid:
        solo = run_federated(dataclasses.replace(cfg,
                                                 selector=r.config.selector))
        _assert_parity(solo, r)
        assert r.dispatches == 1
    assert grid[0].shapley_evals > 0 and grid[1].shapley_evals == 0

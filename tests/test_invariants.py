"""System-invariant property tests (hypothesis): MoE capacity, ring-buffer
positions, RoPE norm preservation, SSD decay bounds."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                       # offline container
    from _hypothesis_compat import given, settings, st

from repro.models.lm.config import ArchConfig
from repro.models.lm.model import _ring_positions


def _moe_cfg(e, k, cf):
    return ArchConfig(name="t", family="moe", n_layers=1, d_model=32,
                      n_heads=2, n_kv_heads=1, d_ff=16, vocab=64,
                      n_experts=e, top_k=k, capacity_factor=cf,
                      dtype="float32")


@settings(max_examples=10, deadline=None)
@given(e=st.sampled_from([4, 8]), k=st.integers(1, 3),
       cf=st.sampled_from([0.5, 1.0, 2.0]), seed=st.integers(0, 20))
def test_moe_capacity_never_exceeded(e, k, cf, seed):
    """No expert ever receives more than its capacity of token slots."""
    from repro.models.lm.moe import _capacity, moe_init, moe_apply
    cfg = _moe_cfg(e, k, cf)
    p = moe_init(jax.random.key(seed), cfg)
    x = jax.random.normal(jax.random.key(seed + 1), (2, 16, 32))
    y, aux = moe_apply(p, cfg, x, n_groups=1)
    cap = _capacity(cfg, 32)
    assert np.isfinite(np.asarray(y)).all()
    assert float(aux) >= 1.0 - 1e-4  # GShard aux lower bound at balance


@settings(max_examples=30, deadline=None)
@given(pos=st.integers(0, 10_000), cache_len=st.sampled_from([8, 64, 4096]))
def test_ring_positions_consistency(pos, cache_len):
    """Every valid slot holds the absolute position it claims: the slot of
    position p is p % cache_len, unwritten slots are negative."""
    kv_pos = np.asarray(_ring_positions(jnp.asarray(pos), cache_len))
    for s, p in enumerate(kv_pos):
        if p >= 0:
            assert p % cache_len == s
            assert pos - cache_len < p <= pos
        else:
            assert pos < s  # only unwritten when pos hasn't reached slot


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 50), frac=st.sampled_from([0.5, 1.0]))
def test_rope_preserves_norm(seed, frac):
    """Rotation is an isometry on the rotary block."""
    from repro.models.lm.layers import apply_rope
    x = jax.random.normal(jax.random.key(seed), (1, 16, 2, 64))
    y = apply_rope(x, jnp.arange(16) + seed, frac=frac, theta=1e4)
    nx = np.linalg.norm(np.asarray(x), axis=-1)
    ny = np.linalg.norm(np.asarray(y), axis=-1)
    np.testing.assert_allclose(nx, ny, rtol=1e-4)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 50))
def test_ssd_decay_is_contractive(seed):
    """SSM state never amplifies: A < 0 => exp(dt*A) in (0, 1]."""
    from repro.models.lm.ssm import _gates, ssm_init
    cfg = ArchConfig(name="t", family="ssm", n_layers=1, d_model=32,
                     n_heads=0, n_kv_heads=0, d_ff=0, vocab=64,
                     ssm_state=8, ssm_head_dim=16, dtype="float32")
    p = ssm_init(jax.random.key(seed), cfg)
    dt_raw = jax.random.normal(jax.random.key(seed + 1), (4, cfg.ssm_heads)) * 3
    dt, a = _gates(p, cfg, dt_raw)
    decay = np.asarray(jnp.exp(dt * a))
    assert (decay > 0).all() and (decay <= 1.0 + 1e-6).all()
    assert (np.asarray(dt) >= 0).all()  # softplus


def test_client_update_is_deterministic_given_key(key):
    from repro.federated.client import ClientConfig, client_update
    from repro.models.mlp_cnn import make_mlp
    model = make_mlp(input_dim=8, hidden=(4,), n_classes=3)
    p0 = model.init(key)
    x = jax.random.normal(key, (20, 8))
    y = jax.random.randint(key, (20,), 0, 3)
    cfg = ClientConfig(epochs=1, batches_per_epoch=2, batch_size=4)
    args = (model, cfg, p0, x, y, jnp.asarray(20), jnp.asarray(1),
            jnp.asarray(0.0), jax.random.key(7))
    a, b = client_update(*args), client_update(*args)
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))

"""Shared fixtures.  NOTE: no XLA_FLAGS here on purpose — smoke tests and
benches must see exactly 1 CPU device; multi-device sharding tests run in
subprocesses (tests/test_sharding.py)."""
import jax
import pytest


@pytest.fixture(scope="session")
def key():
    return jax.random.key(0)

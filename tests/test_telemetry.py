"""repro.telemetry (DESIGN.md §15): bit-neutrality of every telemetry
mode across engines, the JSONL schema validator, the compile/execute
wall-time split, provenance-stamped bench artifacts, and the report CLI.

The load-bearing contract: telemetry off / host-side / live-tap must
produce bit-identical selections, params, and eval curves — observation
never perturbs the experiment, including across a segment-boundary
kill/resume.
"""
import dataclasses
import json

import jax
import numpy as np
import pytest

from repro.federated.client import ClientConfig
from repro.federated.server import FLConfig, run_federated
from repro.telemetry import (
    SCHEMA_VERSION, CompileTimer, Telemetry, TelemetryError, provenance,
    read_events, validate_events, write_bench_json,
)
from repro.telemetry.report import render_table, summarize

TINY = dict(n_clients=8, m=3, rounds=6, n_train=600, n_val=100, n_test=100,
            eval_every=3,
            client=ClientConfig(epochs=2, batches_per_epoch=2, batch_size=16))


def _flat(params):
    return np.concatenate([np.ravel(np.asarray(l))
                           for l in jax.tree.leaves(params)])


def _assert_bitwise(a, b):
    for t, (sa, sb) in enumerate(zip(a.selections, b.selections)):
        np.testing.assert_array_equal(sa, sb, err_msg=f"round {t}")
    np.testing.assert_array_equal(_flat(a.params), _flat(b.params))
    assert a.test_acc == b.test_acc
    assert a.val_loss == b.val_loss
    assert a.dispatches == b.dispatches


# ---- neutrality: off / host-side / live-tap ------------------------------

@pytest.mark.parametrize("engine", ["loop", "batched", "scan"])
def test_telemetry_is_bit_neutral(engine):
    """Attaching a sink (and, on scan, the in-scan live tap) changes no
    output bit and adds no dispatches."""
    cfg = FLConfig(engine=engine, selector="greedyfed", **TINY)
    off = run_federated(cfg)
    tel = Telemetry()
    host = run_federated(cfg, telemetry=tel)
    _assert_bitwise(off, host)
    assert validate_events(tel.events) == len(tel.events)
    kinds = [e["event"] for e in tel.events]
    assert kinds[0] == "run_start" and kinds[-1] == "run_end"
    assert kinds.count("round_metrics") == cfg.rounds
    assert kinds.count("eval") == cfg.rounds // cfg.eval_every

    if engine == "scan":
        tap = Telemetry(live_tap=True)
        live = run_federated(cfg, telemetry=tap)
        _assert_bitwise(off, live)
        taps = [e for e in tap.events if e["event"] == "round_tap"]
        assert len(taps) == cfg.rounds
        assert {e["round"] for e in taps} == set(range(cfg.rounds))
        assert all(e["origin"] == "device" for e in taps)
        validate_events(tap.events)   # taps exempt from round ordering


def test_round_metrics_carry_the_run():
    """The host-side stream is the authoritative record: selections, SV,
    eval spend, and byte accounting must match the FLResult."""
    cfg = FLConfig(engine="scan", selector="greedyfed", **TINY)
    tel = Telemetry()
    res = run_federated(cfg, telemetry=tel)
    rounds = [e for e in tel.events if e["event"] == "round_metrics"]
    assert [r["selections"] for r in rounds] == \
        [list(map(int, s)) for s in res.selections]
    assert sum(r["utility_evals"] for r in rounds) == res.shapley_evals
    assert sum(r["upload_bytes"] for r in rounds) == res.upload_bytes
    assert sum(r["download_bytes"] for r in rounds) == res.download_bytes
    assert all(len(r["sv"]) == cfg.m for r in rounds)
    evals = [e for e in tel.events if e["event"] == "eval"]
    assert [(e["round"] + 1, e["test_acc"]) for e in evals] == \
        [(t, pytest.approx(a)) for t, a in res.test_acc]
    end = tel.events[-1]
    assert end["event"] == "run_end"
    assert end["rounds"] == cfg.rounds
    assert end["sv_truncation_rate"] is not None


def test_grid_kill_resume_with_telemetry(tmp_path):
    """A telemetry-observed segmented grid, killed at a segment boundary
    and resumed, matches the unobserved unsegmented grid bit-for-bit —
    with checkpoint/segment events flowing and the stream validating."""
    from repro.grid import GridSpec, run_grid

    base = FLConfig(engine="scan", selector="greedyfed",
                    **dict(TINY, rounds=4, eval_every=2))
    gspec = GridSpec.product(base, selectors=["greedyfed", "fedavg"],
                             seeds=[0])
    ref = run_grid(gspec)   # no telemetry, no segments: the oracle

    path = str(tmp_path / "events.jsonl")
    ckpt = str(tmp_path / "ckpt")
    with Telemetry(path, heartbeat_every_s=1e9) as tel:
        stopped = run_grid(gspec, rounds_per_segment=2, checkpoint_dir=ckpt,
                           max_segments=1, telemetry=tel)
        assert stopped is None   # killed after one dispatched segment
        resumed = run_grid(gspec, rounds_per_segment=2, checkpoint_dir=ckpt,
                           telemetry=tel)
    for r0, r1 in zip(ref.results, resumed.results):
        np.testing.assert_array_equal(
            np.asarray(r0.selections), np.asarray(r1.selections))
        np.testing.assert_array_equal(_flat(r0.params), _flat(r1.params))
        assert r0.test_acc == r1.test_acc

    events = read_events(path)
    assert validate_events(events) == len(events)
    kinds = [e["event"] for e in events]
    assert kinds.count("run_start") == 2      # killed run + resumed run
    assert "checkpoint_save" in kinds and "checkpoint_load" in kinds
    assert kinds.count("segment_end") == kinds.count("segment_start")
    saves = [e for e in events if e["event"] == "checkpoint_save"]
    assert all(e["nbytes"] > 0 and e["path"].endswith(".npz")
               for e in saves)
    # per-cell attribution at segment boundaries: every cell's full curve
    per_cell = {}
    for e in events:
        if e["event"] == "round_metrics":
            per_cell.setdefault(e["cell"], []).append(e["round"])
    assert per_cell[0] == per_cell[1] == list(range(base.rounds))


# ---- the compile/execute wall-time split ---------------------------------

def test_compile_timer_attributes_fresh_compiles():
    with CompileTimer() as ct:
        jax.jit(lambda x: x * 3.14159 + 2.71828)(np.arange(7.0)).block_until_ready()
    assert ct.seconds > 0.0
    # warm re-dispatch of the SAME executable registers ~nothing
    f = jax.jit(lambda x: x + 1.0)
    f(np.arange(3.0)).block_until_ready()   # compile outside any timer
    with CompileTimer() as ct2:
        f(np.arange(3.0)).block_until_ready()
    assert ct2.seconds == 0.0


def test_flresult_wall_time_split():
    cfg = FLConfig(engine="batched", selector="fedavg", **TINY)
    res = run_federated(cfg)
    assert res.compile_time_s >= 0.0 and res.execute_time_s >= 0.0
    assert res.execute_time_s == pytest.approx(
        max(res.wall_time_s - res.compile_time_s, 0.0))


# ---- the pure-python schema validator ------------------------------------

def _stream(*payloads):
    """Build a well-formed envelope chain around the given payloads."""
    return [dict({"v": SCHEMA_VERSION, "seq": i, "t_s": float(i)}, **p)
            for i, p in enumerate(payloads)]


def test_validator_accepts_a_well_formed_stream():
    ev = _stream(
        {"event": "run_start", "run_id": "r0", "kind": "solo"},
        {"event": "round_metrics", "round": 0, "selections": [1],
         "epochs": [2], "utility_evals": 0, "sv_truncated": False,
         "upload_bytes": 8, "download_bytes": 8},
        {"event": "round_metrics", "round": 1, "selections": [0],
         "epochs": [2], "utility_evals": 0, "sv_truncated": False,
         "upload_bytes": 8, "download_bytes": 8},
        {"event": "run_end", "wall_time_s": 1.0})
    assert validate_events(ev) == 4


def test_validator_rejects_unknown_event():
    with pytest.raises(TelemetryError, match="unknown type"):
        validate_events(_stream({"event": "made_up"}))
    with pytest.raises(TelemetryError, match="unknown event type"):
        Telemetry().emit("made_up")


def test_validator_rejects_missing_required_field():
    with pytest.raises(TelemetryError, match="missing required"):
        validate_events(_stream({"event": "eval", "round": 0,
                                 "test_acc": 0.5}))   # no val_loss
    with pytest.raises(TelemetryError, match="missing required"):
        Telemetry().emit("compile")                   # no seconds


def test_validator_rejects_version_and_envelope_skew():
    bad = _stream({"event": "run_end", "wall_time_s": 1.0})
    bad[0]["v"] = SCHEMA_VERSION + 1
    with pytest.raises(TelemetryError, match="schema version"):
        validate_events(bad)
    with pytest.raises(TelemetryError, match="envelope"):
        validate_events([{"event": "run_end", "wall_time_s": 1.0}])


def test_validator_rejects_broken_seq_chain():
    ev = _stream({"event": "run_start", "run_id": "r", "kind": "solo"},
                 {"event": "run_end", "wall_time_s": 1.0})
    ev[1]["seq"] = 5
    with pytest.raises(TelemetryError, match="seq chain"):
        validate_events(ev)


def test_validator_rejects_nonmonotonic_rounds_per_cell():
    rm = {"event": "round_metrics", "selections": [0], "epochs": [1],
          "utility_evals": 0, "sv_truncated": False, "upload_bytes": 0,
          "download_bytes": 0}
    # same round twice in one cell scope -> reject
    with pytest.raises(TelemetryError, match="not increasing"):
        validate_events(_stream(dict(rm, round=1, cell=0),
                                dict(rm, round=1, cell=0)))
    # distinct cells keep independent round counters -> fine
    validate_events(_stream(dict(rm, round=1, cell=0),
                            dict(rm, round=1, cell=1)))
    # a new run_start resets the scope -> fine
    validate_events(_stream(
        {"event": "run_start", "run_id": "a", "kind": "solo"},
        dict(rm, round=1),
        {"event": "run_start", "run_id": "b", "kind": "solo"},
        dict(rm, round=1)))


def test_jsonl_roundtrip_and_sanitization(tmp_path):
    """What a reader parses back is exactly the in-memory stream, with
    numpy/jax values already coerced to plain python at emit time."""
    path = str(tmp_path / "ev.jsonl")
    with Telemetry(path) as tel:
        tel.emit("run_start", run_id=tel.run_id, kind="solo")
        tel.emit("round_metrics", round=np.int64(0),
                 selections=np.arange(3), epochs=jax.numpy.ones(3),
                 utility_evals=np.int32(7), sv_truncated=np.bool_(False),
                 upload_bytes=0, download_bytes=0)
        tel.emit("run_end", wall_time_s=np.float32(1.5))
    back = read_events(path)
    assert back == tel.events
    rm = back[1]
    assert rm["selections"] == [0, 1, 2] and rm["utility_evals"] == 7
    assert rm["sv_truncated"] is False
    assert isinstance(back[2]["wall_time_s"], float)


# ---- provenance-stamped bench artifacts ----------------------------------

def test_write_bench_json_stamps_provenance(tmp_path):
    path = str(tmp_path / "BENCH_x.json")
    write_bench_json(path, {"schema": "bench_x/v1",
                            "latency_us": np.float64(12.5)})
    with open(path) as f:
        report = json.load(f)
    prov = report["provenance"]
    for field in ("git_rev", "timestamp", "backend", "device_count",
                  "jax_version", "python_version"):
        assert field in prov
    assert prov["backend"] == jax.default_backend()
    assert report["latency_us"] == 12.5
    with pytest.raises(ValueError, match="schema"):
        write_bench_json(str(tmp_path / "bad.json"), {"latency_us": 1})


def test_provenance_fields():
    prov = provenance()
    assert prov["device_count"] == jax.device_count()
    assert prov["jax_version"] == jax.__version__


# ---- the report CLI ------------------------------------------------------

def test_report_summarize_and_cli(tmp_path, capsys):
    from repro.telemetry.report import main

    cfg = FLConfig(engine="scan", selector="greedyfed", **TINY)
    path = str(tmp_path / "run.jsonl")
    with Telemetry(path) as tel:
        run_federated(cfg, telemetry=tel)
    rows = summarize(read_events(path))
    assert len(rows) == 1
    row = rows[0]
    assert row["kind"] == "solo" and row["selector"] == "greedyfed"
    assert row["rounds"] == cfg.rounds
    assert row["utility_evals"] > 0
    assert row["wall_s"] is not None and row["compile_s"] is not None
    table = render_table(rows)
    assert "greedyfed" in table and "rounds" in table

    assert main([path, "--validate"]) == 0
    out = capsys.readouterr().out
    assert "greedyfed" in out

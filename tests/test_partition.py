"""Dirichlet x power-law partitioning properties."""
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                       # offline container
    from _hypothesis_compat import given, settings, st

from repro.federated.partition import (
    dirichlet_partition, partition_summary, power_law_fractions,
)


@settings(max_examples=10, deadline=None)
@given(n=st.integers(2, 50), seed=st.integers(0, 100))
def test_power_law_fractions_normalised(n, seed):
    rng = np.random.default_rng(seed)
    q = power_law_fractions(n, rng)
    assert q.shape == (n,)
    np.testing.assert_allclose(q.sum(), 1.0, rtol=1e-9)
    assert (q > 0).all()


def test_partition_is_disjoint_cover():
    rng = np.random.default_rng(0)
    labels = rng.integers(0, 10, 2000)
    parts = dirichlet_partition(labels, 20, alpha=0.5, rng=rng)
    all_idx = np.concatenate(parts)
    assert len(all_idx) == len(set(all_idx.tolist())), "indices must be disjoint"
    assert len(all_idx) <= 2000
    assert all(p.size >= 2 for p in parts)


def test_alpha_controls_label_skew():
    """Lower alpha => lower per-client label entropy (more skew)."""
    rng = np.random.default_rng(0)
    labels = rng.integers(0, 10, 4000)
    ent = {}
    for alpha in (1e-4, 100.0):
        parts = dirichlet_partition(labels, 30, alpha=alpha,
                                    rng=np.random.default_rng(1))
        ent[alpha] = partition_summary(parts, labels)["label_entropy_mean"]
    assert ent[1e-4] < ent[100.0] * 0.5, ent

"""Checkpoint roundtrip: pytrees and FL server state — plus the §19
integrity contract (atomic writes, sha256 digests, corrupt-checkpoint
fallback and bounded segment retry)."""
import glob
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.ckpt import (
    CheckpointCorruptError, load_pytree, load_server_state, save_pytree,
    save_server_state,
)


def test_pytree_roundtrip(tmp_path, key):
    tree = {"layer0": {"w": jax.random.normal(key, (4, 5)),
                       "b": jnp.zeros(5)},
            "head": {"w": jnp.ones((5, 2), jnp.float32)}}
    path = str(tmp_path / "ckpt.npz")
    save_pytree(path, tree)
    out = load_pytree(path, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_structure_mismatch_raises(tmp_path, key):
    tree = {"a": jnp.zeros(3)}
    path = str(tmp_path / "c.npz")
    save_pytree(path, tree)
    with pytest.raises(ValueError):
        load_pytree(path, {"b": jnp.zeros(3)})


def test_segment_carry_roundtrip(tmp_path, key):
    """A scan-segment carry — params + device selector state + typed rng
    key — survives save/load bit-exactly (the resume contract of
    DESIGN.md §12), including the typed-PRNG-key encode/decode."""
    from repro.checkpoint.ckpt import load_carry, save_carry
    from repro.core.selection_jax import (
        init_device_state, make_selector_spec,
    )
    from repro.engine.round_engine import SegmentCarry

    spec = make_selector_spec("greedyfed", n_clients=6, m=2)
    state = init_device_state(spec, seed=3)
    state = state._replace(
        valuation=state.valuation._replace(
            sv=jax.random.normal(key, (6,))))
    carry = SegmentCarry(
        params={"w": jax.random.normal(key, (4, 2)), "b": jnp.zeros(2)},
        sel_state=state,
        key=jax.random.split(jax.random.key(7), 3),
        eval_slot=jnp.asarray(2, jnp.int32))
    path = str(tmp_path / "carry.npz")
    save_carry(path, carry)
    out = load_carry(path, carry)
    assert jax.dtypes.issubdtype(out.key.dtype, jax.dtypes.prng_key)
    np.testing.assert_array_equal(jax.random.key_data(out.key),
                                  jax.random.key_data(carry.key))
    for a, b in zip(jax.tree.leaves(carry), jax.tree.leaves(out)):
        np.testing.assert_array_equal(
            np.asarray(jax.random.key_data(a) if hasattr(a, "dtype")
                       and jax.dtypes.issubdtype(a.dtype,
                                                 jax.dtypes.prng_key)
                       else a),
            np.asarray(jax.random.key_data(b) if hasattr(b, "dtype")
                       and jax.dtypes.issubdtype(b.dtype,
                                                 jax.dtypes.prng_key)
                       else b))


def test_server_state_roundtrip(tmp_path, key):
    params = {"w": jax.random.normal(key, (3, 3))}
    path = str(tmp_path / "server.npz")
    save_server_state(path, params=params, sv=np.arange(5.0),
                      counts=np.arange(5), round_idx=17, seed=3)
    st = load_server_state(path, params)
    assert st["round"] == 17 and st["seed"] == 3
    np.testing.assert_array_equal(st["sv"], np.arange(5.0))
    np.testing.assert_array_equal(np.asarray(st["params"]["w"]),
                                  np.asarray(params["w"]))


# ------------------------------------------------ §19 integrity contract --
def _tree(key):
    return {"w": jax.random.normal(key, (4, 5)), "b": jnp.zeros(5)}


def test_atomic_write_leaves_no_tmp_and_stamps_digests(tmp_path, key):
    tree = _tree(key)
    path = str(tmp_path / "c.npz")
    save_pytree(path, tree)
    assert not glob.glob(str(tmp_path / "*.tmp"))
    with open(str(tmp_path / "c.manifest.json")) as f:
        manifest = json.load(f)
    assert sorted(manifest["digests"]) == sorted(manifest["keys"])
    assert len(manifest["digests"]) == len(jax.tree.leaves(tree))


def test_truncated_npz_raises_corrupt_not_valueerror(tmp_path, key):
    """A kill mid-write (simulated by truncation) must surface as
    CheckpointCorruptError — the fallback signal — not a generic error."""
    tree = _tree(key)
    path = str(tmp_path / "c.npz")
    save_pytree(path, tree)
    with open(path, "r+b") as f:
        f.truncate(os.path.getsize(path) // 2)
    with pytest.raises(CheckpointCorruptError):
        load_pytree(path, tree)


def test_digest_tamper_detected(tmp_path, key):
    """Bit rot that still parses as a valid npz is caught by the per-leaf
    sha256: flip the recorded digest and the load must refuse."""
    tree = _tree(key)
    path = str(tmp_path / "c.npz")
    save_pytree(path, tree)
    mpath = str(tmp_path / "c.manifest.json")
    with open(mpath) as f:
        manifest = json.load(f)
    k = sorted(manifest["digests"])[0]
    manifest["digests"][k] = "0" * 64
    with open(mpath, "w") as f:
        json.dump(manifest, f)
    with pytest.raises(CheckpointCorruptError):
        load_pytree(path, tree)


def test_missing_checkpoint_is_not_corrupt(tmp_path, key):
    with pytest.raises(FileNotFoundError):
        load_pytree(str(tmp_path / "absent.npz"), _tree(key))


def test_digestless_manifest_tolerated(tmp_path, key):
    """Pre-§19 checkpoints carry no digests: they load (unverified)."""
    tree = _tree(key)
    path = str(tmp_path / "c.npz")
    save_pytree(path, tree)
    mpath = str(tmp_path / "c.manifest.json")
    with open(mpath) as f:
        manifest = json.load(f)
    del manifest["digests"]
    with open(mpath, "w") as f:
        json.dump(manifest, f)
    out = load_pytree(path, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def _tiny_grid_spec():
    from repro.federated.client import ClientConfig
    from repro.federated.server import FLConfig
    from repro.grid import GridSpec

    cfg = FLConfig(
        dataset="mnist", selector="greedyfed", engine="scan",
        shapley_max_iters=10, n_clients=8, m=3, rounds=6, n_train=600,
        n_val=100, n_test=100, eval_every=3,
        client=ClientConfig(epochs=2, batches_per_epoch=2, batch_size=16))
    return GridSpec.product(cfg, selectors=["greedyfed"], seeds=[0, 1])


def test_corrupt_segment_checkpoint_falls_back_bit_identical(tmp_path):
    """Kill-mid-write drill: corrupt the LAST segment checkpoint, resume.
    The loader must flag it (`checkpoint_corrupt`), fall back to the
    previous boundary, recompute forward, and end bit-identical to the
    uninterrupted run."""
    from repro.grid import run_grid
    from repro.telemetry import Telemetry, validate_events

    spec = _tiny_grid_spec()
    d = str(tmp_path / "ck")
    whole = run_grid(spec, rounds_per_segment=3, checkpoint_dir=d)
    ckpts = sorted(glob.glob(os.path.join(d, "*.npz")))
    assert ckpts
    with open(ckpts[-1], "r+b") as f:
        f.truncate(64)
    tel = Telemetry()
    resumed = run_grid(spec, rounds_per_segment=3, checkpoint_dir=d,
                       telemetry=tel)
    for a, b in zip(whole.results, resumed.results):
        np.testing.assert_array_equal(
            np.asarray(a.sv_final), np.asarray(b.sv_final))
        for la, lb in zip(jax.tree.leaves(a.params),
                          jax.tree.leaves(b.params)):
            np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
        assert a.final_acc == b.final_acc
    validate_events(tel.events)
    assert any(ev["event"] == "checkpoint_corrupt" for ev in tel.events)


def test_segment_retry_bounded(monkeypatch):
    """A transient executor failure inside a segment dispatch is retried
    (with a `segment_retry` event) up to `retries`; past the budget the
    error propagates."""
    import repro.grid.segments as segments
    from repro.grid import run_grid
    from repro.telemetry import Telemetry

    spec = _tiny_grid_spec()
    real = segments.jitted_segment_step

    def flaky_factory(fails: int):
        state = {"left": fails}

        def factory(model, ccfg, seg_spec, vmapped=False):
            step = real(model, ccfg, seg_spec, vmapped=vmapped)

            def wrapped(*args):
                if state["left"] > 0:
                    state["left"] -= 1
                    raise RuntimeError("transient executor failure")
                return step(*args)

            return wrapped

        return factory

    clean = run_grid(spec)
    monkeypatch.setattr(segments, "jitted_segment_step", flaky_factory(1))
    tel = Telemetry()
    retried = run_grid(spec, retries=1, telemetry=tel)
    for a, b in zip(clean.results, retried.results):
        np.testing.assert_array_equal(
            np.asarray(a.sv_final), np.asarray(b.sv_final))
    assert sum(ev["event"] == "segment_retry" for ev in tel.events) == 1

    monkeypatch.setattr(segments, "jitted_segment_step", flaky_factory(2))
    with pytest.raises(RuntimeError, match="transient"):
        run_grid(spec, retries=1, isolate_cells=False)

"""Checkpoint roundtrip: pytrees and FL server state."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.ckpt import (
    load_pytree, load_server_state, save_pytree, save_server_state,
)


def test_pytree_roundtrip(tmp_path, key):
    tree = {"layer0": {"w": jax.random.normal(key, (4, 5)),
                       "b": jnp.zeros(5)},
            "head": {"w": jnp.ones((5, 2), jnp.float32)}}
    path = str(tmp_path / "ckpt.npz")
    save_pytree(path, tree)
    out = load_pytree(path, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_structure_mismatch_raises(tmp_path, key):
    tree = {"a": jnp.zeros(3)}
    path = str(tmp_path / "c.npz")
    save_pytree(path, tree)
    with pytest.raises(ValueError):
        load_pytree(path, {"b": jnp.zeros(3)})


def test_server_state_roundtrip(tmp_path, key):
    params = {"w": jax.random.normal(key, (3, 3))}
    path = str(tmp_path / "server.npz")
    save_server_state(path, params=params, sv=np.arange(5.0),
                      counts=np.arange(5), round_idx=17, seed=3)
    st = load_server_state(path, params)
    assert st["round"] == 17 and st["seed"] == 3
    np.testing.assert_array_equal(st["sv"], np.arange(5.0))
    np.testing.assert_array_equal(np.asarray(st["params"]["w"]),
                                  np.asarray(params["w"]))

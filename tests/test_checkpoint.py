"""Checkpoint roundtrip: pytrees and FL server state."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.ckpt import (
    load_pytree, load_server_state, save_pytree, save_server_state,
)


def test_pytree_roundtrip(tmp_path, key):
    tree = {"layer0": {"w": jax.random.normal(key, (4, 5)),
                       "b": jnp.zeros(5)},
            "head": {"w": jnp.ones((5, 2), jnp.float32)}}
    path = str(tmp_path / "ckpt.npz")
    save_pytree(path, tree)
    out = load_pytree(path, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_structure_mismatch_raises(tmp_path, key):
    tree = {"a": jnp.zeros(3)}
    path = str(tmp_path / "c.npz")
    save_pytree(path, tree)
    with pytest.raises(ValueError):
        load_pytree(path, {"b": jnp.zeros(3)})


def test_segment_carry_roundtrip(tmp_path, key):
    """A scan-segment carry — params + device selector state + typed rng
    key — survives save/load bit-exactly (the resume contract of
    DESIGN.md §12), including the typed-PRNG-key encode/decode."""
    from repro.checkpoint.ckpt import load_carry, save_carry
    from repro.core.selection_jax import (
        init_device_state, make_selector_spec,
    )
    from repro.engine.round_engine import SegmentCarry

    spec = make_selector_spec("greedyfed", n_clients=6, m=2)
    state = init_device_state(spec, seed=3)
    state = state._replace(
        valuation=state.valuation._replace(
            sv=jax.random.normal(key, (6,))))
    carry = SegmentCarry(
        params={"w": jax.random.normal(key, (4, 2)), "b": jnp.zeros(2)},
        sel_state=state,
        key=jax.random.split(jax.random.key(7), 3),
        eval_slot=jnp.asarray(2, jnp.int32))
    path = str(tmp_path / "carry.npz")
    save_carry(path, carry)
    out = load_carry(path, carry)
    assert jax.dtypes.issubdtype(out.key.dtype, jax.dtypes.prng_key)
    np.testing.assert_array_equal(jax.random.key_data(out.key),
                                  jax.random.key_data(carry.key))
    for a, b in zip(jax.tree.leaves(carry), jax.tree.leaves(out)):
        np.testing.assert_array_equal(
            np.asarray(jax.random.key_data(a) if hasattr(a, "dtype")
                       and jax.dtypes.issubdtype(a.dtype,
                                                 jax.dtypes.prng_key)
                       else a),
            np.asarray(jax.random.key_data(b) if hasattr(b, "dtype")
                       and jax.dtypes.issubdtype(b.dtype,
                                                 jax.dtypes.prng_key)
                       else b))


def test_server_state_roundtrip(tmp_path, key):
    params = {"w": jax.random.normal(key, (3, 3))}
    path = str(tmp_path / "server.npz")
    save_server_state(path, params=params, sv=np.arange(5.0),
                      counts=np.arange(5), round_idx=17, seed=3)
    st = load_server_state(path, params)
    assert st["round"] == 17 and st["seed"] == 3
    np.testing.assert_array_equal(st["sv"], np.arange(5.0))
    np.testing.assert_array_equal(np.asarray(st["params"]["w"]),
                                  np.asarray(params["w"]))

"""Launch-layer unit tests that don't need multiple devices."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, TUNED_OVERRIDES, get_config
from repro.launch.roofline import collective_bytes_from_text, model_flops
from repro.launch.shapes import (
    SHAPES, batch_struct, decode_structs, pad_vocab, shape_applicable,
)


def test_shapes_registry_matches_assignment():
    assert SHAPES["train_4k"].seq_len == 4096
    assert SHAPES["train_4k"].global_batch == 256
    assert SHAPES["prefill_32k"].global_batch == 32
    assert SHAPES["decode_32k"].global_batch == 128
    assert SHAPES["long_500k"].seq_len == 524288
    assert SHAPES["long_500k"].kind == "decode"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_long500k_applicability_rule(arch):
    cfg = get_config(arch)
    ok, why = shape_applicable(cfg, SHAPES["long_500k"])
    expect = arch in ("mamba2_370m", "hymba_1_5b", "h2o_danube_3_4b")
    assert ok == expect, (arch, why)


def test_pad_vocab_multiple_and_identity():
    cfg = get_config("mamba2_370m")
    padded = pad_vocab(cfg)
    assert padded.vocab % 16 == 0 and padded.vocab >= cfg.vocab
    cfg2 = get_config("kimi_k2_1t_a32b")
    assert pad_vocab(cfg2).vocab == cfg2.vocab  # already divisible


@pytest.mark.parametrize("arch", ["internvl2_76b", "whisper_medium",
                                  "tinyllama_1_1b"])
def test_batch_struct_has_frontend_inputs(arch):
    cfg = get_config(arch)
    bs = batch_struct(cfg, SHAPES["prefill_32k"])
    assert bs["tokens"].shape == (32, 32768)
    if cfg.frontend == "vision":
        assert bs["patches"].shape == (32, 256, cfg.d_model)
    if cfg.frontend == "audio":
        assert bs["frames"].shape == (32, 1500, cfg.d_model)


def test_decode_structs_ring_cache_is_window_bounded():
    cfg = get_config("h2o_danube_3_4b")           # SWA window 4096
    cache, batch = decode_structs(cfg, SHAPES["long_500k"])
    assert cache["k"].shape[2] == cfg.window, "ring cache must be O(window)"
    cfg2 = get_config("tinyllama_1_1b")           # full attention
    cache2, _ = decode_structs(cfg2, SHAPES["decode_32k"])
    assert cache2["k"].shape[2] == 32768


def test_collective_parser_counts_and_weights():
    hlo = """
  %ar = f32[16,128]{1,0} all-reduce(f32[16,128]{1,0} %x), replica_groups={}
  %ag.1 = bf16[4,256]{1,0} all-gather-start(bf16[2,256]{1,0} %y), dim=0
  %ag.2 = bf16[4,256]{1,0} all-gather-done(bf16[4,256]{1,0} %ag.1)
  %a2a = f32[8,8]{1,0} all-to-all(f32[8,8]{1,0} %z)
  %other = f32[4]{0} add(f32[4]{0} %a, f32[4]{0} %b)
"""
    out = collective_bytes_from_text(hlo)
    assert out["counts"]["all-reduce"] == 1
    assert out["counts"]["all-gather"] == 1        # -done not double-counted
    assert out["by_kind"]["all-reduce"] == 16 * 128 * 4
    assert out["by_kind"]["all-gather"] == 4 * 256 * 2
    # weighted total doubles the all-reduce
    assert out["weighted_total"] == (2 * 16 * 128 * 4 + 4 * 256 * 2
                                     + 8 * 8 * 4)


def test_model_flops_train_vs_decode_scaling():
    cfg = get_config("tinyllama_1_1b")
    t = model_flops(cfg, SHAPES["train_4k"])
    d = model_flops(cfg, SHAPES["decode_32k"])
    # train: 6*N*B*S; decode: 2*N*B
    assert t / d == pytest.approx(3 * 256 * 4096 / 128, rel=1e-6)


def test_tuned_configs_apply_perf_overrides():
    cfg = get_config("hymba_1_5b", tuned=True)
    assert cfg.parallelism == "dp" and cfg.attn_remat and cfg.ssm_chunk == 64
    base = get_config("hymba_1_5b")
    assert base.parallelism == "tp", "baseline must stay paper-literal"
    for arch in TUNED_OVERRIDES:
        get_config(arch, tuned=True)  # all resolvable

"""GTG-Shapley (Alg. 2) correctness: exact-oracle match, truncation,
additivity/symmetry properties (hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                       # offline container
    from _hypothesis_compat import given, settings, st

from repro.core.aggregation import subset_average, tree_stack
from repro.core.shapley import exact_shapley, gtg_shapley
from repro.core.shapley_batched import (
    gtg_shapley_batched, gtg_shapley_streaming,
)


def _toy(m=4, d=3, seed=0):
    clients = [{"w": jax.random.normal(jax.random.key(seed + i + 1), (d,))}
               for i in range(m)]
    stacked = tree_stack(clients)
    n_k = jnp.arange(1.0, m + 1.0) * 10
    w_prev = {"w": jnp.zeros(d)}
    target = jax.random.normal(jax.random.key(seed + 99), (d,))

    def utility(p):
        return -jnp.sum((p["w"] - target) ** 2)

    return stacked, n_k, w_prev, utility


def test_gtg_matches_exact_oracle():
    stacked, n_k, w_prev, utility = _toy()
    sv_exact = exact_shapley(stacked, n_k, w_prev, utility)
    sv_mc, stats = gtg_shapley(stacked, n_k, w_prev, utility,
                               jax.random.key(0), eps=1e-7, max_iters=400,
                               convergence_tol=0.005, convergence_rounds=5)
    np.testing.assert_allclose(np.asarray(sv_mc), np.asarray(sv_exact),
                               atol=0.15)
    assert int(stats.utility_evals) > 0


def test_batched_gtg_matches_exact_oracle():
    stacked, n_k, w_prev, utility = _toy()
    sv_exact = exact_shapley(stacked, n_k, w_prev, utility)
    sv_b, _ = gtg_shapley_batched(stacked, n_k, w_prev, utility,
                                  jax.vmap(utility), jax.random.key(1),
                                  n_perms=512, use_kernel=False)
    np.testing.assert_allclose(np.asarray(sv_b), np.asarray(sv_exact),
                               atol=0.25)


def test_streaming_gtg_matches_exact_oracle():
    stacked, n_k, w_prev, utility = _toy()
    sv_exact = exact_shapley(stacked, n_k, w_prev, utility)
    sv_s, _ = gtg_shapley_streaming(stacked, n_k, w_prev, utility,
                                    jax.vmap(utility), jax.random.key(1),
                                    n_perms=512, use_kernel=False)
    np.testing.assert_allclose(np.asarray(sv_s), np.asarray(sv_exact),
                               atol=0.25)


@pytest.mark.parametrize("seed", [0, 1])
def test_streaming_matches_dense_batched(seed):
    """Streaming and dense draw the SAME walks from the same key, so they
    compute the same MC average — equal to f32 association tolerance —
    with identical stats."""
    stacked, n_k, w_prev, utility = _toy(seed=seed)
    args = (stacked, n_k, w_prev, utility, jax.vmap(utility),
            jax.random.key(seed))
    sv_d, st_d = gtg_shapley_batched(*args, n_perms=64, use_kernel=False)
    sv_s, st_s = gtg_shapley_streaming(*args, n_perms=64, use_kernel=False)
    np.testing.assert_allclose(np.asarray(sv_s), np.asarray(sv_d),
                               atol=1e-5)
    assert int(st_s.utility_evals) == int(st_d.utility_evals)
    assert int(st_s.iterations) == int(st_d.iterations) == 64
    assert not bool(st_s.truncated_round)


def test_streaming_matches_dense_on_truncated_round():
    """A constant utility fires between-round truncation on both paths:
    zero SV, zero walks, only the two gate evaluations."""
    stacked, n_k, w_prev, _ = _toy()
    const = lambda p: jnp.array(3.14)  # noqa: E731
    for fn in (gtg_shapley_batched, gtg_shapley_streaming):
        sv, st = fn(stacked, n_k, w_prev, const, jax.vmap(const),
                    jax.random.key(0), n_perms=32, use_kernel=False)
        assert bool(st.truncated_round)
        assert np.all(np.asarray(sv) == 0.0)
        # the pinned stats fix: no permutations were walked
        assert int(st.iterations) == 0
        assert int(st.utility_evals) == 2


@pytest.mark.parametrize("sv_chunk", [1, 4, 32, 3, 12, -1])
def test_streaming_chunked_bitwise_identity(sv_chunk):
    """Every sv_chunk — one model, one walk, everything, a sub-walk
    non-divisor (3 -> 1 walk/chunk), a padded non-divisor (12 -> 3
    walks/chunk, which does NOT divide n_perms=8 and exercises the
    filler-walk pad + truncating slice), and the forced unchunked pass —
    is BIT-identical to the auto default: chunk boundaries fall on whole
    walks and the walk accumulation is strictly left-to-right."""
    stacked, n_k, w_prev, utility = _toy(m=4)
    args = (stacked, n_k, w_prev, utility, jax.vmap(utility),
            jax.random.key(2))
    base, _ = gtg_shapley_streaming(*args, n_perms=8, sv_chunk=0,
                                    use_kernel=False)
    sv, _ = gtg_shapley_streaming(*args, n_perms=8, sv_chunk=sv_chunk,
                                  use_kernel=False)
    np.testing.assert_array_equal(np.asarray(sv), np.asarray(base))


def test_additivity_sums_to_total_gain():
    """sum_k SV_k == U(w^{t+1}) - U(w^t) (paper Section III-B)."""
    stacked, n_k, w_prev, utility = _toy(m=5)
    sv = exact_shapley(stacked, n_k, w_prev, utility)
    w_full = subset_average(stacked, n_k, jnp.ones((5,)))
    gain = utility(w_full) - utility(w_prev)
    np.testing.assert_allclose(float(jnp.sum(sv)), float(gain), rtol=1e-4)


def test_between_round_truncation():
    stacked, n_k, w_prev, _ = _toy()
    sv, stats = gtg_shapley(stacked, n_k, w_prev, lambda p: jnp.array(3.14),
                            jax.random.key(0), eps=1e-4)
    assert bool(stats.truncated_round)
    assert np.all(np.asarray(sv) == 0.0)


def test_symmetric_clients_get_equal_value():
    """Identical updates with identical n_k must tie (SV symmetry)."""
    base = {"w": jnp.array([1.0, 2.0])}
    stacked = tree_stack([base, base, {"w": jnp.array([-1.0, 0.0])}])
    n_k = jnp.array([10.0, 10.0, 10.0])
    w_prev = {"w": jnp.zeros(2)}

    def utility(p):
        return -jnp.sum(p["w"] ** 2)

    sv = exact_shapley(stacked, n_k, w_prev, utility)
    assert abs(float(sv[0] - sv[1])) < 1e-5


@settings(max_examples=15, deadline=None)
@given(m=st.integers(2, 5), seed=st.integers(0, 50))
def test_property_additivity_mc(m, seed):
    """Property: the MC estimator preserves additivity for any utility."""
    stacked, n_k, w_prev, utility = _toy(m=m, seed=seed)
    sv, stats = gtg_shapley(stacked, n_k, w_prev, utility,
                            jax.random.key(seed), eps=1e-9, max_iters=20,
                            convergence_tol=0.0)
    w_full = subset_average(stacked, n_k, jnp.ones((m,)))
    gain = float(utility(w_full) - utility(w_prev))
    if not bool(stats.truncated_round):
        np.testing.assert_allclose(float(jnp.sum(sv)), gain, rtol=1e-3,
                                   atol=1e-4)

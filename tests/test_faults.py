"""repro.faults (DESIGN.md §19): deterministic fault injection, the
in-round quarantine screen, masked SV/aggregation, and the noise_level
lift — identity off, containment on, stream parity across engines."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.aggregation import normalized_weights, weighted_average
from repro.faults import (
    CODE_CRASH, CODE_NAN, CODE_NONE, CODE_SIGN_FLIP, TINY_WEIGHT, FaultSpec,
    apply_faults, draw_fault_table, harden_cohort,
)
from repro.federated.client import ClientConfig
from repro.federated.server import FLConfig, run_federated, setup_run

TINY = dict(n_clients=8, m=3, rounds=6, n_train=600, n_val=100, n_test=100,
            eval_every=3,
            client=ClientConfig(epochs=2, batches_per_epoch=2, batch_size=16))

FAULTS = FaultSpec(rate=0.4, kinds=("nan", "sign_flip", "crash"), scale=10.0)


def _base(**kw):
    kw = dict(selector="greedyfed", engine="scan", shapley_max_iters=10,
              **TINY) | kw
    return FLConfig(**kw)


def _flat(params):
    return np.concatenate([np.ravel(np.asarray(l))
                           for l in jax.tree.leaves(params)])


def _assert_bitwise(a, b):
    assert len(a.selections) == len(b.selections)
    for t, (sa, sb) in enumerate(zip(a.selections, b.selections)):
        np.testing.assert_array_equal(sa, sb, err_msg=f"round {t}")
    np.testing.assert_array_equal(_flat(a.params), _flat(b.params))
    np.testing.assert_array_equal(np.asarray(a.sv_final),
                                  np.asarray(b.sv_final))


# ------------------------------------------------------------- the table --
def test_fault_table_deterministic_gated_and_bounded():
    spec = FaultSpec(rate=0.5, kinds=("nan", "crash"), start_round=3)
    t1 = draw_fault_table(spec, 10, 16, np.random.default_rng(7))
    t2 = draw_fault_table(spec, 10, 16, np.random.default_rng(7))
    np.testing.assert_array_equal(t1, t2)
    assert t1.shape == (10, 16) and t1.dtype == np.int32
    # start_round zeroes the prefix; codes only from the declared kinds
    assert (t1[:3] == CODE_NONE).all()
    assert set(np.unique(t1)) <= {CODE_NONE, CODE_NAN, CODE_CRASH}
    assert (t1[3:] != CODE_NONE).any()
    # rate 0 never fires, but consumes the same two rng draws
    rng_a, rng_b = np.random.default_rng(7), np.random.default_rng(7)
    zero = draw_fault_table(FaultSpec(rate=0.0), 10, 16, rng_a)
    draw_fault_table(spec, 10, 16, rng_b)
    assert (zero == CODE_NONE).all()
    assert rng_a.random() == rng_b.random()   # stream position identical


def test_fault_spec_validation():
    with pytest.raises(ValueError):
        FaultSpec(kinds=("gremlin",)).validate()
    with pytest.raises(ValueError):
        FaultSpec(kinds=()).validate()
    with pytest.raises(ValueError):
        FaultSpec(rate=1.5).validate()
    with pytest.raises(ValueError):
        FaultSpec(start_round=-1).validate()


def test_rng_stream_unchanged_by_fault_gating():
    """The fault-table draw sits strictly after every existing draw and is
    gated on `faults is not None`: a faulty config reproduces the exact
    host stream (fractions/sigma/epochs) of its fault-free twin."""
    plain = setup_run(_base())
    faulty = setup_run(_base(faults=FAULTS))
    np.testing.assert_array_equal(plain.fractions, faulty.fractions)
    np.testing.assert_array_equal(plain.sigma_k_all, faulty.sigma_k_all)
    assert plain.fault_table is None
    assert faulty.fault_table.shape == (TINY["rounds"], TINY["n_clients"])


# ------------------------------------------------- hardening (unit level) --
def test_apply_faults_untouched_rows_bitwise():
    key = jax.random.key(0)
    p = {"w": jax.random.normal(key, (4, 3))}
    w = {"w": p["w"][None] + 0.1 * jax.random.normal(key, (5, 4, 3))}
    codes = jnp.asarray([CODE_NONE, CODE_NAN, CODE_SIGN_FLIP, CODE_CRASH,
                         CODE_NONE], jnp.int32)
    out = apply_faults(w, p, codes, 10.0)["w"]
    # code-0 and CRASH rows pass through bitwise; NaN rows are poisoned;
    # sign-flip rows are the scaled mirror delta
    np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(w["w"][0]))
    np.testing.assert_array_equal(np.asarray(out[3]), np.asarray(w["w"][3]))
    np.testing.assert_array_equal(np.asarray(out[4]), np.asarray(w["w"][4]))
    assert np.isnan(np.asarray(out[1])).all()
    np.testing.assert_allclose(
        np.asarray(out[2]),
        np.asarray(p["w"] - 10.0 * (w["w"][2] - p["w"])), rtol=1e-6)


def test_harden_cohort_masks_and_tiny_weight_absorbs():
    key = jax.random.key(1)
    p = {"w": jax.random.normal(key, (4,))}
    w = {"w": p["w"][None] + 0.05 * jax.random.normal(key, (3, 4))}
    n_k = jnp.asarray([10.0, 20.0, 30.0])
    codes = jnp.asarray([CODE_NONE, CODE_NAN, CODE_NONE], jnp.int32)
    h = harden_cohort(w, p, n_k, codes,
                      faults=FaultSpec(kinds=("nan",)), quarantine=True,
                      z=8.0)
    np.testing.assert_array_equal(np.asarray(h.ok), [True, False, True])
    assert int(h.quarantined) == 1
    # quarantined row substituted by w_prev, weights masked
    np.testing.assert_array_equal(np.asarray(h.stacked["w"][1]),
                                  np.asarray(p["w"]))
    np.testing.assert_array_equal(np.asarray(h.n_k_agg), [10.0, 0.0, 30.0])
    assert float(h.n_k_sv[1]) == TINY_WEIGHT
    # the SV-weight scheme: a TINY_WEIGHT row sharing a prefix with any
    # honest weight >= 1 is absorbed exactly in f32 — the prefix average
    # is bitwise as if the quarantined row were absent
    two = {"w": jnp.stack([w["w"][0], p["w"]])}
    with_tiny = weighted_average(
        two, normalized_weights(jnp.asarray([10.0, TINY_WEIGHT])))
    alone = weighted_average(
        {"w": w["w"][:1]}, normalized_weights(jnp.asarray([10.0])))
    np.testing.assert_array_equal(np.asarray(with_tiny["w"]),
                                  np.asarray(alone["w"]))


def test_harden_cohort_static_passthrough():
    w = {"w": jnp.ones((2, 3))}
    p = {"w": jnp.zeros((3,))}
    n_k = jnp.asarray([1.0, 2.0])
    h = harden_cohort(w, p, n_k, jnp.zeros((2,), jnp.int32),
                      faults=None, quarantine=False, z=8.0)
    assert h.stacked["w"] is w["w"] and h.n_k_agg is n_k and h.n_k_sv is n_k


# ------------------------------------------------------ e2e: identity off --
@pytest.mark.parametrize("engine", ["loop", "batched", "scan"])
def test_quarantine_on_clean_run_bitwise_identical(engine):
    """The §19 identity contract: compiling the hardened path in but never
    firing it leaves selections/params/sv/eval curves bit-identical."""
    plain = run_federated(_base(engine=engine))
    hard = run_federated(_base(engine=engine, quarantine=True))
    _assert_bitwise(plain, hard)
    assert hard.quarantined_total == 0
    assert [a for _, a in plain.test_acc] == [a for _, a in hard.test_acc]
    assert plain.upload_bytes == hard.upload_bytes


# --------------------------------------------------- e2e: containment on --
def test_nan_storm_poisons_unscreened_and_is_quarantined_screened():
    """rate=1.0 nan faults: without the screen the model is destroyed;
    with it every faulty row is masked, every round degenerates to
    w_prev, and the params stay bitwise at their init."""
    storm = FaultSpec(rate=1.0, kinds=("nan",))
    poisoned = run_federated(_base(faults=storm, quarantine=False))
    assert not np.isfinite(_flat(poisoned.params)).all()
    clean = run_federated(_base(faults=storm, quarantine=True))
    assert np.isfinite(_flat(clean.params)).all()
    assert clean.quarantined_total == TINY["rounds"] * TINY["m"]
    np.testing.assert_array_equal(_flat(clean.params),
                                  _flat(setup_run(_base()).params))
    # quarantined clients never enter the byte ledger
    assert clean.upload_bytes == 0
    # and never reach the SV walks: the masked rounds contribute zero
    np.testing.assert_array_equal(np.asarray(clean.sv_final),
                                  np.zeros(TINY["n_clients"], np.float32))


def test_crash_faults_mask_without_screen():
    """CRASH rows (mid-round dropout) are masked by the fault code alone —
    no quarantine screen needed, payloads never aggregated."""
    crash = FaultSpec(rate=1.0, kinds=("crash",))
    res = run_federated(_base(faults=crash, quarantine=False))
    assert res.quarantined_total == TINY["rounds"] * TINY["m"]
    assert res.upload_bytes == 0
    np.testing.assert_array_equal(_flat(res.params),
                                  _flat(setup_run(_base()).params))


def test_byzantine_sign_flip_screened():
    """Scaled sign-flip updates are finite, so only the norm screen can
    catch them.  A median screen is only sound against a cohort MINORITY
    (a byzantine majority owns the median — m=3 can hide 2 fired rows),
    so the guarantee under test is: every fired row in a minority-fired
    round is quarantined."""
    byz = FaultSpec(rate=0.3, kinds=("sign_flip",), scale=10.0)
    cfg = _base(faults=byz, quarantine=True)
    res = run_federated(cfg)
    table = setup_run(cfg).fault_table
    fired = [int((table[t][np.asarray(sel)] != CODE_NONE).sum())
             for t, sel in enumerate(res.selections)]
    minority = sum(f for f in fired if f <= (TINY["m"] - 1) // 2)
    assert minority > 0
    assert res.quarantined_total >= minority
    assert np.isfinite(_flat(res.params)).all()


@pytest.mark.parametrize("engine", ["loop", "batched"])
def test_engine_parity_under_faults(engine):
    """All engines read the same pre-drawn table and run the same
    hardening ops: streams and ledgers identical under injected faults."""
    scan = run_federated(_base(faults=FAULTS, quarantine=True))
    other = run_federated(_base(engine=engine, faults=FAULTS,
                                quarantine=True))
    _assert_bitwise(scan, other)
    assert scan.quarantined_total == other.quarantined_total
    assert scan.upload_bytes == other.upload_bytes


def test_grid_with_faults_matches_solo_and_telemetry_counts():
    from repro.grid import GridSpec, run_grid
    from repro.telemetry import Telemetry, validate_events

    cfg = _base(faults=FAULTS, quarantine=True)
    solo = run_federated(cfg)
    tel = Telemetry()
    grid = run_grid(GridSpec.product(cfg, selectors=["greedyfed", "random"],
                                     seeds=[0]), telemetry=tel)
    cell = grid.cell("greedyfed", 0)
    _assert_bitwise(solo, cell)
    assert cell.quarantined_total == solo.quarantined_total
    validate_events(tel.events)
    # the authoritative round_metrics stream carries the per-round counts
    emitted = sum(ev.get("quarantined", 0) for ev in tel.events
                  if ev["event"] == "round_metrics")
    assert emitted == solo.quarantined_total + \
        grid.cell("random", 0).quarantined_total


# ------------------------------------------------- satellite: noise_level --
def test_noise_level_zero_is_bitwise_default():
    """noise_level=0 is gated out of the rng stream entirely."""
    _assert_bitwise(run_federated(_base()),
                    run_federated(_base(noise_level=0.0)))


def test_noise_level_perturbs_and_grid_axis_matches_solo():
    from repro.grid import GridCell, GridSpec, run_grid

    cfg = _base(selector="fedavg", noise_level=0.2)
    noisy = run_federated(cfg)
    plain = run_federated(_base(selector="fedavg"))
    assert not np.array_equal(_flat(noisy.params), _flat(plain.params))
    # sigma fold is on the host table: per-client noise is heterogeneous
    s = setup_run(cfg)
    assert len(np.unique(s.sigma_k_all)) > 1
    # noise_level is a grid axis (per-cell sigma operand, not jit-static)
    grid = run_grid(GridSpec(_base(selector="fedavg"), (
        GridCell("fedavg", 0),
        GridCell("fedavg", 0, overrides={"noise_level": 0.2}))))
    _assert_bitwise(plain, grid.results[0])
    _assert_bitwise(noisy, grid.results[1])

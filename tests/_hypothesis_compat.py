"""Import-time fallback when `hypothesis` is not installed (offline CI).

Property-based tests decorate with `@given(...)`; without hypothesis the
decorator replaces the test with a skip marker so the module still collects
and every plain test in it runs.  Usage in test modules:

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:                       # offline container
        from _hypothesis_compat import given, settings, st
"""
import pytest


def given(*_args, **_kwargs):
    def deco(fn):
        return pytest.mark.skip(reason="hypothesis not installed")(fn)
    return deco


def settings(*_args, **_kwargs):
    return lambda fn: fn


class _AnyStrategy:
    """Stands in for `hypothesis.strategies`: any attribute is a callable
    returning None, enough for decorator-argument evaluation at import."""

    def __getattr__(self, _name):
        return lambda *a, **k: None


st = _AnyStrategy()

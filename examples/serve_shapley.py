"""Serving-style example: batched decode with a prefilled KV cache, plus
per-request contribution accounting via the batched Shapley machinery.

    PYTHONPATH=src python examples/serve_shapley.py

Demonstrates the serving path the decode_32k / long_500k dry-run shapes
lower: prefill a batch of prompts, then step the ring-buffer KV cache (SWA
arch => O(window) memory).  As a twist that exercises the paper's valuation
machinery outside training, we Shapley-attribute the batch's mean logprob
across the requests (clients == requests, utility == batch objective).
"""
import dataclasses
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.lm import model as M


def main() -> None:
    cfg = get_config("h2o_danube_3_4b").reduced(n_layers=4, d_model=256)
    cfg = dataclasses.replace(cfg, vocab=512, dtype="float32", window=64)
    key = jax.random.key(0)
    params = M.init_params(cfg, key)

    b, prompt_len, gen_len = 4, 256, 32
    tokens = jax.random.randint(key, (b, prompt_len), 0, cfg.vocab)

    t0 = time.time()
    cache, logits = M.prefill_step(cfg, params, {"tokens": tokens},
                                   cache_len=prompt_len + gen_len)
    print(f"# prefill {b}x{prompt_len} in {time.time()-t0:.1f}s "
          f"(SWA ring cache: {cfg.window} slots/layer)")

    decode = jax.jit(lambda c, tok: M.decode_step(cfg, params, c,
                                                  {"token": tok}))
    out = []
    logprob_sum = jnp.zeros((b,))
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    t0 = time.time()
    for i in range(gen_len):
        out.append(tok)
        cache, logits = decode(cache, tok)
        lp = jax.nn.log_softmax(logits, -1)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        logprob_sum += jnp.take_along_axis(lp, tok[:, None], 1)[:, 0]
    dt = time.time() - t0
    print(f"# decoded {gen_len} steps x {b} seqs in {dt:.1f}s "
          f"({b*gen_len/dt:.1f} tok/s on CPU)")
    gen = jnp.stack(out, 1)
    print("# generated token ids (first 10 per request):")
    for r in range(b):
        print(f"  req{r}: {gen[r,:10].tolist()}  mean logprob "
              f"{float(logprob_sum[r])/gen_len:.3f}")

    # Shapley attribution of the batch objective across requests
    from repro.core.shapley import exact_shapley
    from repro.core.aggregation import tree_stack
    contrib = [{"lp": logprob_sum[r][None]} for r in range(b)]
    stacked = tree_stack(contrib)
    sv = exact_shapley(stacked, jnp.ones(b), {"lp": jnp.zeros(1)},
                       lambda p: jnp.sum(p["lp"]))
    print(f"# request Shapley values of batch logprob: "
          f"{np.round(np.asarray(sv), 3).tolist()}")


if __name__ == "__main__":
    main()

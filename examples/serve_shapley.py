"""Serving-style example: batched decode with a prefilled KV cache, plus
per-request contribution accounting via the batched Shapley machinery.

    PYTHONPATH=src python examples/serve_shapley.py [--events out.jsonl]

Demonstrates the serving path the decode_32k / long_500k dry-run shapes
lower: prefill a batch of prompts, then step the ring-buffer KV cache (SWA
arch => O(window) memory).  As a twist that exercises the paper's valuation
machinery outside training, we Shapley-attribute the batch's mean logprob
across the requests (clients == requests, utility == batch objective).

`--events` streams the run through repro.telemetry (kind="serve"):
run_start with provenance, a compile event (jit trace+lower+compile split
via jax.monitoring) carrying the decode step's cost card (§17), a
`serve_step` per decode step, the per-request SV as a final
`round_metrics`, run_end — then prints the report-table summary.
`--trace-dir` additionally opens a profiler capture window around the
decode loop (requires --events; the `profile` event records per-stage
wall seconds recovered from the trace).
"""
import argparse
import dataclasses
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.lm import model as M


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--events", default=None,
                    help="telemetry JSONL path (default: off)")
    ap.add_argument("--trace-dir", default=None,
                    help="profiler capture dir (needs --events)")
    args = ap.parse_args(argv)

    from repro.telemetry import CompileTimer, Telemetry, provenance, stage

    tel = (Telemetry(path=args.events, trace_dir=args.trace_dir)
           if args.events else None)
    ctimer = CompileTimer()

    cfg = get_config("h2o_danube_3_4b").reduced(n_layers=4, d_model=256)
    cfg = dataclasses.replace(cfg, vocab=512, dtype="float32", window=64)
    key = jax.random.key(0)
    params = M.init_params(cfg, key)

    b, prompt_len, gen_len = 4, 256, 32
    tokens = jax.random.randint(key, (b, prompt_len), 0, cfg.vocab)
    t_run = time.perf_counter()
    if tel is not None:
        tel.emit("run_start", run_id=tel.run_id, kind="serve",
                 batch=b, prompt_len=prompt_len, gen_len=gen_len,
                 window=cfg.window, provenance=provenance())

    t0 = time.perf_counter()
    with ctimer, stage("train"):   # prefill is the serving "train" stage
        cache, logits = M.prefill_step(cfg, params, {"tokens": tokens},
                                       cache_len=prompt_len + gen_len)
        jax.block_until_ready(logits)
    print(f"# prefill {b}x{prompt_len} in {time.perf_counter()-t0:.1f}s "
          f"(SWA ring cache: {cfg.window} slots/layer)")

    decode = jax.jit(lambda c, tok: M.decode_step(cfg, params, c,
                                                  {"token": tok}))
    out = []
    logprob_sum = jnp.zeros((b,))
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    t0 = time.perf_counter()
    from repro.telemetry import trace_capture
    with ctimer, trace_capture(tel, label="serve"):
        for i in range(gen_len):
            out.append(tok)
            with stage("eval"):
                cache, logits = decode(cache, tok)
            lp = jax.nn.log_softmax(logits, -1)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            logprob_sum += jnp.take_along_axis(lp, tok[:, None], 1)[:, 0]
            if tel is not None:
                tel.emit("serve_step", step=i,
                         tokens=int(b * (i + 1)))
        jax.block_until_ready(logprob_sum)
    dt = time.perf_counter() - t0
    print(f"# decoded {gen_len} steps x {b} seqs in {dt:.1f}s "
          f"({b*gen_len/dt:.1f} tok/s on CPU)")
    gen = jnp.stack(out, 1)
    print("# generated token ids (first 10 per request):")
    for r in range(b):
        print(f"  req{r}: {gen[r,:10].tolist()}  mean logprob "
              f"{float(logprob_sum[r])/gen_len:.3f}")

    # Shapley attribution of the batch objective across requests
    from repro.core.shapley import exact_shapley
    from repro.core.aggregation import tree_stack
    contrib = [{"lp": logprob_sum[r][None]} for r in range(b)]
    stacked = tree_stack(contrib)
    with ctimer, stage("shapley"):
        sv = exact_shapley(stacked, jnp.ones(b), {"lp": jnp.zeros(1)},
                           lambda p: jnp.sum(p["lp"]))
    print(f"# request Shapley values of batch logprob: "
          f"{np.round(np.asarray(sv), 3).tolist()}")

    if tel is not None:
        from repro.telemetry import cached_cost_card
        wall = time.perf_counter() - t_run
        # the decode step dominates the serving loop; its cost card
        # (AOT probe on avals — safe after dispatch) rides the event
        tel.emit("compile", seconds=ctimer.seconds,
                 program="prefill+decode+shapley",
                 cost_card=cached_cost_card(decode, cache, tok))
        # the per-request attribution, in the stream's round vocabulary:
        # one "round", every request selected, exact SV = 2^b evaluations
        tel.emit("round_metrics", round=0, selections=list(range(b)),
                 epochs=[gen_len] * b, sv=np.asarray(sv),
                 utility_evals=2 ** b, sv_truncated=False,
                 upload_bytes=0, download_bytes=0)
        tel.emit("run_end", wall_time_s=wall,
                 compile_time_s=ctimer.seconds,
                 execute_time_s=max(wall - ctimer.seconds, 0.0),
                 tokens_per_sec=b * gen_len / dt,
                 utility_evals=2 ** b)
        tel.close()
        from repro.telemetry.report import render_table, summarize
        from repro.telemetry import read_events
        print(f"# telemetry -> {args.events}")
        print(render_table(summarize(read_events(args.events))))


if __name__ == "__main__":
    main()

"""End-to-end driver: federated fine-tuning of a transformer LM with
GreedyFed client selection — the paper's technique applied to the assigned
architecture pool.

    PYTHONPATH=src python examples/federated_lm.py [--arch tinyllama_1_1b]
        [--rounds 30] [--d-model 256] [--layers 4]

N simulated clients each hold a private synthetic token stream with a
client-specific skew (distinct "dialects" = heterogeneity).  Each round the
server selects M clients (GreedyFed), every selected client runs E local
AdamW steps from the server model, the server aggregates (ModelAverage),
values contributions with GTG-Shapley on a held-out validation stream, and
updates cumulative SVs.  Defaults give a ~5M-param model for CPU; at
--d-model 1024 --layers 8 the same script is the ~100M-scale driver.
"""
import argparse
import dataclasses
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.aggregation import normalized_weights, tree_stack, weighted_average
from repro.core.selection_jax import (
    DeviceSelectionContext, device_select, device_update, init_device_state,
    make_selector_spec, poc_d_schedule,
)
from repro.core.shapley import gtg_shapley
from repro.models.lm import model as M


def make_client_streams(key, n_clients, vocab, length, n_dialects=4):
    """Synthetic heterogeneous corpora: bigram chains per dialect."""
    keys = jax.random.split(key, n_dialects)
    # dialect d prefers tokens in its own band -> learnable structure
    streams = []
    qualities = []
    for c in range(n_clients):
        d = c % n_dialects
        band = vocab // n_dialects
        lo = d * band
        k = jax.random.fold_in(keys[d], c)
        # low-id clients get cleaner (more predictable) streams
        noise = 0.1 + 0.8 * (c / n_clients)
        clean = lo + jnp.arange(length) % band
        rand = jax.random.randint(k, (length,), 0, vocab)
        mask = jax.random.bernoulli(k, noise, (length,))
        streams.append(jnp.where(mask, rand, clean).astype(jnp.int32))
        qualities.append(1.0 - noise)
    return jnp.stack(streams), np.asarray(qualities)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama_1_1b")
    ap.add_argument("--rounds", type=int, default=30)
    ap.add_argument("--clients", type=int, default=12)
    ap.add_argument("--select", type=int, default=3)
    ap.add_argument("--local-steps", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--selector", default="greedyfed")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced(n_layers=args.layers,
                                        d_model=args.d_model)
    cfg = dataclasses.replace(cfg, vocab=1024, dtype="float32")
    key = jax.random.key(0)
    params = M.init_params(cfg, key)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"# federated LM: {cfg.name} ({n_params/1e6:.1f}M params), "
          f"N={args.clients} M={args.select} T={args.rounds}")

    streams, quality = make_client_streams(key, args.clients, cfg.vocab,
                                           8192)
    val_stream = streams[0][:2048]  # server-side validation stream

    opt_init, train_step = M.make_train_step(cfg)
    train_step = jax.jit(train_step)

    def sample_batch(stream, k):
        starts = jax.random.randint(k, (args.batch,), 0,
                                    stream.shape[0] - args.seq - 1)
        idx = starts[:, None] + jnp.arange(args.seq)
        return {"tokens": stream[idx]}

    @jax.jit
    def client_update(p, stream, k):
        opt = opt_init(p)
        def body(i, carry):
            p, opt, k = carry
            k, kb = jax.random.split(k)
            p, opt, _ = train_step(p, opt, sample_batch(stream, kb))
            return (p, opt, k)
        p, _, _ = jax.lax.fori_loop(0, args.local_steps, body, (p, opt, k))
        return p

    val_batch = {"tokens": val_stream[: (2048 // args.seq) * args.seq]
                 .reshape(-1, args.seq)}

    def utility_fn(p):
        return -M.loss_fn(cfg, p, val_batch)

    # the runtime selector stack (repro.core.selection_jax): a static spec
    # plus a fixed-shape device state — the same pair every engine uses
    spec = make_selector_spec(args.selector, args.clients, args.select)
    state = init_device_state(spec, seed=0)
    d_sched = poc_d_schedule(spec, args.rounds)
    fractions = jnp.ones(args.clients) / args.clients
    n_k = jnp.ones(args.select)

    t0 = time.time()
    print("round,val_loss,selected")
    for t in range(args.rounds):
        key, ks, kl, kr = jax.random.split(key, 4)
        losses = jnp.zeros(args.clients)
        if spec.uses_local_losses:   # Power-of-Choice ranks by w^t loss
            losses = jnp.stack([M.loss_fn(cfg, params, sample_batch(
                streams[c], jax.random.fold_in(kl, c)))
                for c in range(args.clients)])
        ctx = DeviceSelectionContext(data_fractions=fractions,
                                     local_losses=losses,
                                     poc_d=jnp.asarray(d_sched[t]))
        sel, state = device_select(spec, state, ks, ctx)
        updates = [client_update(params, streams[int(c)],
                                 jax.random.fold_in(kr, int(c)))
                   for c in sel]
        stacked = tree_stack(updates)
        sv_round = None
        if spec.uses_shapley:
            sv_round, _ = gtg_shapley(stacked, n_k, params, utility_fn,
                                      jax.random.fold_in(kr, 999),
                                      max_iters=20)
        params = weighted_average(stacked, normalized_weights(n_k))
        state = device_update(spec, state, jnp.asarray(sel),
                              sv_round=sv_round)
        if t % 5 == 0 or t == args.rounds - 1:
            vl = float(-utility_fn(params))
            print(f"{t},{vl:.4f},{list(map(int, sel))}")

    sv = np.asarray(state.valuation.sv)
    rank = sv.argsort()[::-1]
    print(f"# wall {time.time()-t0:.0f}s")
    print(f"# client quality (true):   {np.round(quality, 2).tolist()}")
    print(f"# SV ranking (discovered): {rank.tolist()}")
    # GreedyFed should discover that low-noise clients contribute most
    top_half = set(rank[: args.clients // 2].tolist())
    true_top = set(quality.argsort()[::-1][: args.clients // 2].tolist())
    overlap = len(top_half & true_top) / max(len(true_top), 1)
    print(f"# top-half overlap between SV ranking and true quality: "
          f"{overlap:.2f}")


if __name__ == "__main__":
    main()

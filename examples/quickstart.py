"""Quickstart: GreedyFed vs FedAvg on synthetic MNIST under heterogeneity.

    PYTHONPATH=src python examples/quickstart.py

Runs two small federated trainings (N=20 clients, Dirichlet alpha=1e-4,
T=25 rounds) and prints the accuracy-vs-round comparison — the Fig. 1
phenomenon at laptop scale: after the round-robin valuation phase,
GreedyFed's greedy Shapley selection pulls ahead of uniform sampling.
"""
import sys

sys.path.insert(0, "src")

from repro.data.synth import make_dataset
from repro.federated.client import ClientConfig
from repro.federated.server import FLConfig, run_federated


def main() -> None:
    # difficulty 3.0 + per-client privacy noise: the regime where biased
    # selection matters (EXPERIMENTS.md §Paper-validation); easier settings
    # saturate and every strategy ties
    common = dict(
        dataset="mnist", n_clients=20, m=3, rounds=25,
        dirichlet_alpha=1e-4, privacy_sigma=0.05, seed=0,
        n_train=2500, n_val=300, n_test=500, eval_every=5,
        client=ClientConfig(epochs=3, batches_per_epoch=3, batch_size=32),
    )
    data = make_dataset("mnist", n_train=2500, n_val=300, n_test=500,
                        difficulty=3.0, seed=0)

    results = {}
    for selector in ("greedyfed", "fedavg"):
        print(f"== training with {selector} ==")
        res = run_federated(FLConfig(selector=selector, **common), data=data)
        results[selector] = res
        print(f"   final acc {res.final_acc:.3f} "
              f"(wall {res.wall_time_s:.0f}s, "
              f"shapley evals {res.shapley_evals})")

    print("\nround | greedyfed | fedavg")
    for (r1, a1), (_, a2) in zip(results["greedyfed"].test_acc,
                                 results["fedavg"].test_acc):
        print(f"{r1:5d} | {a1:9.3f} | {a2:6.3f}")

    gf = results["greedyfed"]
    top = gf.sv_final.argsort()[-3:][::-1]
    print(f"\nGreedyFed's top-3 clients by cumulative Shapley value: {top}")
    print(f"their selection counts: {gf.selection_counts[top]}")


if __name__ == "__main__":
    main()

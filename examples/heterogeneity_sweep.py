"""Robustness demo: GreedyFed vs baselines under stragglers + privacy noise.

    PYTHONPATH=src python examples/heterogeneity_sweep.py

Reproduces the Table III/IV phenomenon at laptop scale: with 50% stragglers
AND per-client privacy noise, Shapley-guided selection degrades least,
because noisy/partial contributors earn low cumulative SV and stop being
selected after the round-robin phase.
"""
import sys

sys.path.insert(0, "src")

from repro.data.synth import make_dataset
from repro.federated.client import ClientConfig
from repro.federated.server import FLConfig, run_federated


def main() -> None:
    data = make_dataset("mnist", n_train=2500, n_val=300, n_test=500,
                        difficulty=3.0, seed=1)
    common = dict(
        dataset="mnist", n_clients=20, m=3, rounds=25, dirichlet_alpha=1e-4,
        seed=1, n_train=2500, n_val=300, n_test=500, eval_every=25,
        client=ClientConfig(epochs=3, batches_per_epoch=3, batch_size=32),
    )

    print("setting           | greedyfed | ucb   | fedavg")
    for name, knobs in [
        ("clean", {}),
        ("stragglers x=0.5", {"straggler_frac": 0.5}),
        ("noise sigma=0.1", {"privacy_sigma": 0.1}),
        ("both", {"straggler_frac": 0.5, "privacy_sigma": 0.1}),
    ]:
        accs = {}
        for sel in ("greedyfed", "ucb", "fedavg"):
            res = run_federated(FLConfig(selector=sel, **common, **knobs),
                                data=data)
            accs[sel] = res.final_acc
        print(f"{name:17s} | {accs['greedyfed']:9.3f} | {accs['ucb']:.3f} "
              f"| {accs['fedavg']:.3f}")


if __name__ == "__main__":
    main()

"""Robustness demo: GreedyFed vs baselines under stragglers + privacy noise.

    PYTHONPATH=src python examples/heterogeneity_sweep.py

Reproduces the Table III/IV phenomenon at laptop scale: with 50% stragglers
AND per-client privacy noise, Shapley-guided selection degrades least,
because noisy/partial contributors earn low cumulative SV and stop being
selected after the round-robin phase.

The whole 4-setting x 3-selector sweep is ONE `repro.grid` run: each
(setting, selector) pair is a GridCell whose knob overrides become
per-replica scan operands, the cells are partitioned by capability (the
fedavg column skips GTG-Shapley entirely), and every partition executes
as a single fused dispatch (DESIGN.md §12).
"""
import sys

sys.path.insert(0, "src")

from repro.data.synth import make_dataset
from repro.federated.client import ClientConfig
from repro.federated.server import FLConfig
from repro.grid import GridCell, GridSpec, run_grid

SETTINGS = [
    ("clean", {}),
    ("stragglers x=0.5", {"straggler_frac": 0.5}),
    ("noise sigma=0.1", {"privacy_sigma": 0.1}),
    ("both", {"straggler_frac": 0.5, "privacy_sigma": 0.1}),
]
SELECTORS = ("greedyfed", "ucb", "fedavg")


def main() -> None:
    data = make_dataset("mnist", n_train=2500, n_val=300, n_test=500,
                        difficulty=3.0, seed=1)
    base = FLConfig(
        dataset="mnist", n_clients=20, m=3, rounds=25, dirichlet_alpha=1e-4,
        seed=1, n_train=2500, n_val=300, n_test=500, eval_every=25,
        client=ClientConfig(epochs=3, batches_per_epoch=3, batch_size=32),
    )
    spec = GridSpec(base, tuple(
        GridCell(sel, seed=1, overrides=knobs)
        for _, knobs in SETTINGS for sel in SELECTORS))

    out = run_grid(spec, data=data)
    print(f"{len(spec.cells)} cells, {len(out.partitions)} partitions, "
          f"{out.dispatches} dispatches, {out.wall_time_s:.1f}s")

    print("setting           | greedyfed | ucb   | fedavg")
    results = iter(out.results)
    for name, _ in SETTINGS:
        accs = {sel: next(results).final_acc for sel in SELECTORS}
        print(f"{name:17s} | {accs['greedyfed']:9.3f} | {accs['ucb']:.3f} "
              f"| {accs['fedavg']:.3f}")


if __name__ == "__main__":
    main()

"""repro.telemetry — structured observability for every engine (§15).

`Telemetry` is the sink all engines accept (`telemetry=None` default:
zero dispatches, bit-identical outputs); `events` defines the
schema-versioned JSONL stream and its validator plus the provenance-
stamped BENCH writer; `metrics` aggregates host-side gauges at segment
boundaries; `trace` carries stage annotation, the compile-time split,
and the opt-in in-scan live tap; `report` renders summaries from JSONL.
"""
from repro.telemetry.events import (
    SCHEMA_VERSION, Telemetry, TelemetryError, provenance, read_events,
    validate_events, write_bench_json,
)
from repro.telemetry.metrics import (
    emit_scan_rounds, run_end_payload, segment_counters,
)
from repro.telemetry.trace import (
    CompileTimer, live_sink, named_stage, stage,
)

__all__ = [
    "SCHEMA_VERSION", "Telemetry", "TelemetryError", "provenance",
    "read_events", "validate_events", "write_bench_json",
    "emit_scan_rounds", "run_end_payload", "segment_counters",
    "CompileTimer", "live_sink", "named_stage", "stage",
]

"""repro.telemetry — structured observability for every engine (§15/§17).

`Telemetry` is the sink all engines accept (`telemetry=None` default:
zero dispatches, bit-identical outputs); `events` defines the
schema-versioned JSONL stream and its validator plus the provenance-
stamped BENCH writer; `metrics` aggregates host-side gauges at segment
boundaries; `trace` carries stage annotation, the compile-time split,
and the opt-in in-scan live tap; `report` renders summaries from JSONL.

The §17 analysis tier on top of the stream: `profile` attaches per-
executable cost cards to compile events and drives the opt-in profiler
capture window; `merge` folds per-process JSONL shards into one
validated stream; `regress` diffs the BENCH_*.json artifacts against
committed baselines and keeps the BENCH_trajectory.json ledger.
"""
from repro.telemetry.events import (
    SCHEMA_VERSION, Telemetry, TelemetryError, provenance, read_events,
    read_events_prefix, validate_events, write_bench_json,
)
from repro.telemetry.metrics import (
    emit_scan_rounds, run_end_payload, segment_counters,
)
from repro.telemetry.profile import (
    cached_cost_card, cost_card, stage_wall_from_trace, trace_capture,
)
from repro.telemetry.trace import (
    CompileTimer, live_sink, named_stage, record_spans, stage,
)

__all__ = [
    "SCHEMA_VERSION", "Telemetry", "TelemetryError", "provenance",
    "read_events", "read_events_prefix", "validate_events",
    "write_bench_json",
    "emit_scan_rounds", "run_end_payload", "segment_counters",
    "cached_cost_card", "cost_card", "stage_wall_from_trace",
    "trace_capture",
    "CompileTimer", "live_sink", "named_stage", "record_spans", "stage",
]

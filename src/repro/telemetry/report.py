"""Render a run/grid summary table from a telemetry JSONL stream.

    PYTHONPATH=src python -m repro.telemetry.report events.jsonl [...]

One row per (run, cell): rounds observed, final accuracy, SV spend and
truncation rate, bytes moved, wall/compile/execute seconds, rounds/sec.
`--json` emits `{"schema_version", "rows"}` machine-readably instead
(the embedded version is the stream schema the rows were folded from, so
CI consumers can refuse streams they do not understand); `--validate`
runs the schema validator first and exits nonzero on a malformed stream
— CI can gate on the exit code directly.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Optional

from repro.telemetry.events import (
    SCHEMA_VERSION, TelemetryError, read_events, validate_events,
)


def _fmt(x, nd=3) -> str:
    if x is None:
        return "-"
    if isinstance(x, float):
        return f"{x:.{nd}f}"
    return str(x)


def summarize(events) -> list[dict]:
    """Fold an event stream into one summary row per (run, cell).

    Cells come from `round_metrics`/`eval` events carrying a `cell`
    field (grid runs); solo runs fold into cell None.  Run-level fields
    (wall, compile, kind) come from `run_start`/`run_end` and are
    repeated on each of the run's cell rows.
    """
    rows: list[dict] = []
    run: Optional[dict] = None
    cells: dict = {}

    def _flush():
        nonlocal run, cells
        if run is None:
            return
        if not cells:
            cells[None] = _new_cell()
        for cell_id in sorted(cells, key=lambda c: (c is None, c)):
            c = cells[cell_id]
            rows.append({
                "run_id": run.get("run_id"), "kind": run.get("kind"),
                "selector": c["selector"] or run.get("selector"),
                "cell": cell_id,
                "rounds": c["rounds"],
                "final_acc": c["final_acc"],
                "utility_evals": c["utility_evals"],
                "sv_truncated_rounds": c["sv_truncated_rounds"],
                "upload_mb": c["upload_bytes"] / 1e6,
                "download_mb": c["download_bytes"] / 1e6,
                "quarantined": c["quarantined"],
                "taps": c["taps"],
                "checkpoints": run.get("checkpoints", 0),
                "segments": run.get("segments", 0),
                "fault_events": run.get("fault_events", 0),
                "wall_s": run.get("wall_time_s"),
                "compile_s": run.get("compile_time_s"),
                "execute_s": run.get("execute_time_s"),
                "rounds_per_sec": run.get("rounds_per_sec"),
            })
        run, cells = None, {}

    def _new_cell() -> dict:
        return {"rounds": 0, "final_acc": None, "utility_evals": 0,
                "sv_truncated_rounds": 0, "upload_bytes": 0,
                "download_bytes": 0, "quarantined": 0, "taps": 0,
                "selector": None}

    for ev in events:
        kind = ev["event"]
        if kind == "run_start":
            _flush()
            run = {"run_id": ev.get("run_id"), "kind": ev.get("kind"),
                   "selector": ev.get("selector"), "checkpoints": 0,
                   "segments": 0, "fault_events": 0}
        elif run is None:       # stream fragment without a run_start
            run = {"run_id": None, "kind": None, "selector": None,
                   "checkpoints": 0, "segments": 0, "fault_events": 0}
        if kind in ("round_metrics", "eval", "round_tap"):
            c = cells.setdefault(ev.get("cell"), _new_cell())
            if kind == "round_metrics":
                c["rounds"] += 1
                c["utility_evals"] += ev.get("utility_evals", 0)
                c["sv_truncated_rounds"] += bool(ev.get("sv_truncated"))
                c["upload_bytes"] += ev.get("upload_bytes", 0)
                c["download_bytes"] += ev.get("download_bytes", 0)
                c["quarantined"] += ev.get("quarantined", 0)
            elif kind == "eval":
                c["final_acc"] = ev.get("test_acc")
            else:
                c["taps"] += 1
        elif kind == "segment_end":
            run["segments"] += 1
        elif kind == "checkpoint_save":
            run["checkpoints"] += 1
        elif kind in ("checkpoint_corrupt", "segment_retry", "cell_failed"):
            run["fault_events"] += 1
        elif kind == "run_end":
            for f in ("wall_time_s", "compile_time_s", "execute_time_s",
                      "rounds_per_sec"):
                run[f] = ev.get(f)
            if ev.get("final_acc") is not None and len(cells) <= 1:
                cells.setdefault(None, _new_cell())
                if cells[None]["final_acc"] is None:
                    cells[None]["final_acc"] = ev["final_acc"]
            _flush()
    _flush()
    return rows


_COLUMNS = (
    ("run_id", "run"), ("kind", "kind"), ("selector", "selector"),
    ("cell", "cell"), ("rounds", "rounds"), ("final_acc", "acc"),
    ("utility_evals", "sv_evals"), ("sv_truncated_rounds", "sv_trunc"),
    ("upload_mb", "up_mb"), ("download_mb", "down_mb"),
    ("quarantined", "quar"), ("fault_events", "faults"),
    ("segments", "segs"), ("checkpoints", "ckpts"),
    ("wall_s", "wall_s"), ("compile_s", "compile_s"),
    ("rounds_per_sec", "rounds/s"),
)


def render_table(rows: list[dict]) -> str:
    if not rows:
        return "(no runs in stream)"
    table = [[h for _, h in _COLUMNS]]
    for r in rows:
        table.append([_fmt(r.get(k)) for k, _ in _COLUMNS])
    widths = [max(len(row[i]) for row in table)
              for i in range(len(_COLUMNS))]
    lines = []
    for j, row in enumerate(table):
        lines.append("  ".join(c.rjust(w) for c, w in zip(row, widths)))
        if j == 0:
            lines.append("  ".join("-" * w for w in widths))
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("paths", nargs="+", help="JSONL event files")
    ap.add_argument("--json", action="store_true",
                    help="emit summary rows as JSON instead of a table")
    ap.add_argument("--validate", action="store_true",
                    help="schema-validate the stream before summarising")
    args = ap.parse_args(argv)

    events = []
    for p in args.paths:
        events.extend(read_events(p))
    if args.validate:
        try:
            n = validate_events(events)
        except TelemetryError as e:
            print(f"validation FAILED: {e}", file=sys.stderr)
            return 1
        print(f"# validated {n} events", file=sys.stderr)
    rows = summarize(events)
    if args.json:
        json.dump({"schema_version": SCHEMA_VERSION, "rows": rows},
                  sys.stdout, indent=2)
        print()
    else:
        print(render_table(rows))
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""Host-side counters/gauges over engine outputs — no extra dispatches.

The one-dispatch scan contract (DESIGN.md §11) means per-round telemetry
cannot be observed while the scan runs (short of the opt-in live tap);
instead the engines hand their stacked outputs (`ScanRunOutput`,
`SegmentOutput`) to the helpers here AT SEGMENT BOUNDARIES, where the
arrays are materialising on the host anyway — aggregation costs a device
-> host transfer the result rebuild already pays, and zero dispatches.

  * `emit_scan_rounds` — unrolls a run's stacked (T, ...) outputs into
    per-round `round_metrics` / `eval` events (the authoritative stream;
    the live tap is diagnostics only);
  * `segment_counters` — one segment's aggregate gauges (rounds/sec, SV
    truncation count, utility-eval spend) for `segment_end` events and
    the grid heartbeat;
  * `run_end_payload` — the run-level rollup (rounds/sec, SV truncation
    rate, evals-per-accuracy-point, byte totals, compile/execute split).
"""
from __future__ import annotations

from typing import Optional

import numpy as np


def emit_scan_rounds(tel, out, *, uses_shapley: bool, codec_bytes: int,
                     model_bytes: int, emask, cell: Optional[int] = None,
                     t0: int = 0) -> None:
    """Per-round events from stacked scan outputs (host-side, post-run).

    `out` is a ScanRunOutput (or any object with the same per-round
    stacks) holding (T, M) selections/epochs/sv and (T,) counters;
    `emask` is the cell's (T,) bool eval cadence (schedule.eval_mask) —
    eval events are emitted where it is set, from the same stacked
    accuracy/loss rows the FLResult curve is rebuilt from.
    """
    sels = np.asarray(out.selections)
    epochs = np.asarray(out.epochs)
    sv = np.asarray(out.sv)
    evals = np.asarray(out.utility_evals)
    trunc = np.asarray(out.sv_truncated)
    acc = np.asarray(out.test_acc)
    vloss = np.asarray(out.val_loss)
    emask = np.asarray(emask)
    m = int(sels.shape[1]) if sels.ndim > 1 else 0
    # uploads are charged at the round's ACTUAL granted-cohort size —
    # dropout strategies can grant fewer than m active clients — matching
    # the loop engine's per-selected-client ledger (replicated.py)
    granted = (np.asarray(out.granted) if getattr(out, "granted", None)
               is not None else np.full((sels.shape[0],), m, np.int64))
    # per-round quarantine counts (§19) — absent on pre-fault outputs
    quar = getattr(out, "quarantined", None)
    quar = np.asarray(quar) if quar is not None else None
    extra = {} if cell is None else {"cell": cell}
    for i in range(sels.shape[0]):
        t = t0 + i
        fields = dict(
            round=int(t), selections=sels[i], epochs=epochs[i],
            utility_evals=int(evals[i]), sv_truncated=bool(trunc[i]),
            upload_bytes=codec_bytes * int(granted[i]),
            download_bytes=model_bytes * m, **extra)
        if uses_shapley:
            fields["sv"] = sv[i]
        if quar is not None and quar[i]:
            fields["quarantined"] = int(quar[i])
        tel.emit("round_metrics", **fields)
        if emask[t]:
            tel.emit("eval", round=int(t), test_acc=float(acc[i]),
                     val_loss=float(vloss[i]), **extra)


def segment_counters(out, seconds: float) -> dict:
    """Aggregate gauges of one (possibly replica-stacked) SegmentOutput."""
    evals = np.asarray(out.utility_evals)
    trunc = np.asarray(out.sv_truncated)
    k_rounds = int(evals.shape[-1])
    n_replicas = int(evals.shape[0]) if evals.ndim > 1 else 1
    counters = {
        "rounds": k_rounds,
        "replicas": n_replicas,
        "seconds": seconds,
        "rounds_per_sec": k_rounds / seconds if seconds > 0 else None,
        "utility_evals": int(evals.sum()),
        "sv_truncated_rounds": int(trunc.sum()),
    }
    quar = getattr(out, "quarantined", None)
    if quar is not None:
        counters["quarantined"] = int(np.asarray(quar).sum())
    return counters


def run_end_payload(*, rounds: int, wall_time_s: float,
                    compile_time_s: float, final_acc: float,
                    utility_evals: int, upload_bytes: int,
                    download_bytes: int, sv_rounds: int = 0,
                    truncated_rounds: int = 0, dispatches: int = 0) -> dict:
    """The `run_end` event payload: run-level counters and derived gauges.

    * `rounds_per_sec` uses execute time (wall minus compile) — the
      steady-state number a capacity plan needs; wall stays reported.
    * `sv_truncation_rate` = truncated SV rounds / rounds that ran SV.
    * `evals_per_acc_point` = utility evals per final-accuracy percentage
      point — the "what did the valuation spend buy" gauge the paper's
      budget framing asks for (lower is better; None without evals/acc).
    """
    execute_s = max(wall_time_s - compile_time_s, 0.0)
    acc_points = final_acc * 100.0
    return {
        "wall_time_s": wall_time_s,
        "compile_time_s": compile_time_s,
        "execute_time_s": execute_s,
        "rounds": rounds,
        "rounds_per_sec": rounds / execute_s if execute_s > 0 else None,
        "dispatches": dispatches,
        "final_acc": None if final_acc != final_acc else final_acc,
        "utility_evals": utility_evals,
        "sv_truncation_rate":
            truncated_rounds / sv_rounds if sv_rounds else None,
        "evals_per_acc_point":
            utility_evals / acc_points
            if utility_evals and acc_points == acc_points and acc_points > 0
            else None,
        "upload_bytes": upload_bytes,
        "download_bytes": download_bytes,
    }

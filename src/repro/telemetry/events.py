"""Schema-versioned structured telemetry: the JSONL event stream.

`Telemetry` is the one handle every engine takes (always as an optional
keyword defaulting to None — the telemetry-off path adds zero dispatches
and leaves every output bit-identical, DESIGN.md §15).  Events are
append-only JSONL records with a fixed envelope:

    {"v": 1, "seq": 0, "t_s": 0.000012, "event": "run_start", ...}

  * `v`    — the stream schema version (SCHEMA_VERSION); bump on any
             incompatible field change so downstream parsers can refuse
             streams they do not understand;
  * `seq`  — per-sink monotonic sequence number (gap-free, so a consumer
             can detect a truncated or interleaved stream);
  * `t_s`  — seconds since the sink was created (`time.perf_counter`
             based: monotonic, never wall-clock-adjusted).

Event types and their required payload fields are in `REQUIRED_FIELDS`;
`validate_events` is the pure-python contract checker (the satellite
test gate) — envelope present, types known, seq gap-free, and round
indices strictly increasing per (run, cell) for the host-authoritative
`round_metrics`/`eval` streams.  The device-originated `round_tap`
stream (trace.py) is exempt from ordering: `jax.debug.callback` makes no
cross-round ordering promise.

`provenance()`/`write_bench_json` stamp benchmark artifacts (BENCH_*.json)
with git rev, timestamp, backend, device count, and jax version so every
number on disk says where it came from.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time
import uuid
from typing import Any, IO, Optional

SCHEMA_VERSION = 1

# envelope fields every event carries (emitted by `Telemetry.emit`)
ENVELOPE_FIELDS = ("v", "seq", "t_s", "event")

# event type -> payload fields that MUST be present (beyond the envelope)
# `compile` events may additionally carry a `cost_card` (profile.py): the
# per-executable flops/bytes/peak-memory/roofline block; `profile` events
# close a jax.profiler capture window (trace dir + per-stage wall).
REQUIRED_FIELDS: dict[str, tuple] = {
    "run_start": ("run_id", "kind"),
    "compile": ("seconds",),
    "profile": ("trace_dir",),
    "segment_start": ("segment", "t0"),
    "segment_end": ("segment", "seconds"),
    "round_metrics": ("round", "selections", "epochs", "utility_evals",
                      "sv_truncated", "upload_bytes", "download_bytes"),
    "round_tap": ("round",),          # device-origin live tap (trace.py)
    "serve_step": ("step",),          # serving-tier decode steps
    "eval": ("round", "test_acc", "val_loss"),
    "checkpoint_save": ("path", "nbytes"),
    "checkpoint_load": ("path",),
    # §19 fault/robustness stream: corrupted checkpoints detected at
    # resume, bounded segment retries, and isolated grid-cell failures
    "checkpoint_corrupt": ("path",),
    "segment_retry": ("segment", "attempt"),
    "cell_failed": ("cell", "error"),
    "run_end": ("wall_time_s",),
}

# host-authoritative per-round streams whose `round` index must be
# strictly increasing within one (run, cell); the async `round_tap`
# stream is deliberately NOT here (see module docstring)
_ORDERED_ROUND_EVENTS = ("round_metrics", "eval")


class TelemetryError(ValueError):
    """An event stream violated the schema contract."""


def _sanitize(x: Any) -> Any:
    """Coerce numpy/jax scalars and arrays into plain JSON-able python.

    Done at emit time (not dump time) so the in-memory `events` list a
    test inspects is exactly what a JSONL reader would parse back.
    """
    if isinstance(x, (str, int, float, bool)) or x is None:
        return x
    if isinstance(x, dict):
        return {str(k): _sanitize(v) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [_sanitize(v) for v in x]
    item = getattr(x, "item", None)       # numpy / jax zero-dim scalars
    tolist = getattr(x, "tolist", None)   # numpy / jax arrays
    if tolist is not None and getattr(x, "ndim", 0):
        return _sanitize(tolist())
    if item is not None:
        return _sanitize(item())
    return str(x)


class Telemetry:
    """A telemetry sink: JSONL event stream + throttled progress heartbeat.

    * `path=None` keeps events in memory only (`.events`); with a path,
      every event is appended (and flushed, so an externally killed run
      leaves a readable prefix — the kill/resume contract).
    * `live_tap=True` opts the scan engines into the in-scan
      `jax.debug.callback` stream (`round_tap` events).  Trace-affecting
      but bit-neutral: it recompiles the scan with callbacks attached and
      must not change any output (pinned by tests/test_telemetry.py).
    * `trace_dir` opts the engines into a programmatic
      `jax.profiler.start_trace`/`stop_trace` capture window around the
      run's dispatches (profile.trace_capture): artifacts land in a
      run_id-stamped subdirectory and a `profile` event reports the
      per-stage wall recovered from the §15 span annotations.
    * `heartbeat_every_s` throttles progress lines (0 = every call);
      lines go to `stream` (default stderr), never into the event file.
    """

    def __init__(self, path: Optional[str] = None, *,
                 live_tap: bool = False, heartbeat_every_s: float = 0.0,
                 stream: Optional[IO] = None, run_id: Optional[str] = None,
                 trace_dir: Optional[str] = None):
        self.path = path
        self.live_tap = bool(live_tap)
        self.trace_dir = trace_dir
        self.run_id = run_id or f"run-{uuid.uuid4().hex[:8]}"
        self.events: list[dict] = []
        self.heartbeat_every_s = float(heartbeat_every_s)
        self._stream = stream if stream is not None else sys.stderr
        self._seq = 0
        self._t0 = time.perf_counter()
        self._last_hb = -float("inf")
        self._f: Optional[IO] = None
        if path:
            os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
            self._f = open(path, "a")

    # ---- event stream ----------------------------------------------------
    def emit(self, event: str, **fields) -> dict:
        if event not in REQUIRED_FIELDS:
            raise TelemetryError(f"unknown event type {event!r}; known: "
                                 f"{sorted(REQUIRED_FIELDS)}")
        missing = [f for f in REQUIRED_FIELDS[event] if f not in fields]
        if missing:
            raise TelemetryError(
                f"event {event!r} missing required fields {missing}")
        rec = {"v": SCHEMA_VERSION, "seq": self._seq,
               "t_s": round(time.perf_counter() - self._t0, 6),
               "event": event}
        rec.update({k: _sanitize(v) for k, v in fields.items()})
        self._seq += 1
        self.events.append(rec)
        if self._f is not None:
            json.dump(rec, self._f)
            self._f.write("\n")
            self._f.flush()
        return rec

    # ---- progress heartbeat ---------------------------------------------
    def heartbeat(self, msg: str, *, force: bool = False) -> None:
        now = time.perf_counter()
        if not force and now - self._last_hb < self.heartbeat_every_s:
            return
        self._last_hb = now
        print(f"[telemetry {self.run_id}] {msg}", file=self._stream,
              flush=True)

    # ---- lifecycle -------------------------------------------------------
    def close(self) -> None:
        if self._f is not None:
            self._f.close()
            self._f = None

    def __enter__(self) -> "Telemetry":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def read_events(path: str) -> list[dict]:
    """Parse a JSONL event file back into a list of event dicts."""
    events = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                events.append(json.loads(line))
    return events


def read_events_prefix(path: str) -> tuple[list[dict], Optional[dict]]:
    """Parse a JSONL event file tolerating a truncated/corrupt tail.

    A killed run's append+flush stream leaves a readable prefix whose
    last line may be cut mid-record; this returns `(events, cut)` where
    `events` is the parseable prefix and `cut` is None for a clean file
    or `{"line", "reason", "raw"}` describing the first bad line — the
    cut is REPORTED, never silently swallowed, and everything after it
    is ignored (a flushed-JSONL stream cannot have valid records after
    a corrupt one unless the file was externally edited).
    """
    events: list[dict] = []
    cut = None
    with open(path) as f:
        for i, line in enumerate(f):
            stripped = line.strip()
            if not stripped:
                continue
            try:
                events.append(json.loads(stripped))
            except ValueError as e:
                cut = {"line": i, "reason": str(e), "raw": stripped[:120]}
                break
    return events, cut


def validate_events(events) -> int:
    """Pure-python schema check over an event stream; returns the count.

    Raises TelemetryError on: missing envelope fields, version mismatch,
    unknown event type, non-gap-free `seq`, missing required payload
    fields, or a non-increasing `round` index within one (run, cell) for
    the ordered streams (`round_metrics`, `eval`).  Runs are delimited by
    `run_start` events, so one file may hold many runs (e.g. a killed
    grid resumed into the same path).

    Merged multi-process streams (telemetry.merge) annotate every event
    with its source `shard` and renumber `seq` globally; ordering scopes
    (the seq chain aside) are then tracked per shard, so interleaved
    per-process round streams validate without false positives.
    """
    prev_seq = None
    run_ordinals: dict = {}
    last_round: dict[tuple, int] = {}
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            raise TelemetryError(f"event {i} is not an object: {ev!r}")
        for f in ENVELOPE_FIELDS:
            if f not in ev:
                raise TelemetryError(f"event {i} missing envelope "
                                     f"field {f!r}: {ev}")
        if ev["v"] != SCHEMA_VERSION:
            raise TelemetryError(
                f"event {i} has schema version {ev['v']!r}; this "
                f"validator understands {SCHEMA_VERSION}")
        kind = ev["event"]
        if kind not in REQUIRED_FIELDS:
            raise TelemetryError(f"event {i} has unknown type {kind!r}")
        missing = [f for f in REQUIRED_FIELDS[kind] if f not in ev]
        if missing:
            raise TelemetryError(
                f"event {i} ({kind}) missing required fields {missing}")
        seq = ev["seq"]
        if prev_seq is not None and seq != prev_seq + 1:
            raise TelemetryError(
                f"event {i} breaks the seq chain: {prev_seq} -> {seq}")
        prev_seq = seq
        shard = ev.get("shard")
        if kind == "run_start":
            run_ordinals[shard] = run_ordinals.get(shard, -1) + 1
        if kind in _ORDERED_ROUND_EVENTS:
            scope = (shard, run_ordinals.get(shard, -1), kind,
                     ev.get("cell"))
            rnd = ev["round"]
            if not isinstance(rnd, int):
                raise TelemetryError(
                    f"event {i} ({kind}) round index must be an int, "
                    f"got {rnd!r}")
            if scope in last_round and rnd <= last_round[scope]:
                raise TelemetryError(
                    f"event {i} ({kind}, cell={ev.get('cell')}) round "
                    f"index not increasing: {last_round[scope]} -> {rnd}")
            last_round[scope] = rnd
    return len(events)


# ---- provenance-stamped benchmark artifacts ------------------------------

def _git_rev() -> Optional[str]:
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            timeout=10, cwd=os.path.dirname(os.path.abspath(__file__)))
        return out.stdout.strip() or None if out.returncode == 0 else None
    except (OSError, subprocess.SubprocessError):
        return None


def provenance() -> dict:
    """Where a number came from: rev, time, backend, devices, versions."""
    import jax
    return {
        "git_rev": _git_rev(),
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "jax_version": jax.__version__,
        "python_version": sys.version.split()[0],
    }


def write_bench_json(path: str, report: dict) -> dict:
    """The single BENCH_*.json writer: stamp provenance, dump sorted.

    Every benchmark artifact goes through here (benchmarks/engine_bench
    and friends) so each carries its `schema` tag (the caller's, e.g.
    "bench_selection/v1") plus a `provenance` block — no more hand-rolled
    json.dump blocks with unattributed numbers.
    """
    if "schema" not in report:
        raise ValueError("bench reports must carry a 'schema' tag "
                         "(e.g. 'bench_selection/v1')")
    stamped = dict(report)
    stamped["provenance"] = provenance()
    with open(path, "w") as f:
        json.dump(_sanitize(stamped), f, indent=2, sort_keys=True)
        f.write("\n")
    return stamped

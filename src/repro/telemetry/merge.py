"""Merge per-process telemetry JSONL shards into one validated stream.

    PYTHONPATH=src python -m repro.telemetry.merge shard*.jsonl -o merged.jsonl

A multi-host run gives every process its own `Telemetry` sink (same
`run_id`, per-host file) — the ROADMAP's multi-host-grid prerequisite.
Each shard's envelope is self-consistent (per-sink gap-free `seq`,
monotonic sink-relative `t_s`), so merging is a sort, not a renumber of
anything meaningful:

  1. every shard is gap-checked and schema-validated on its own (a
     truncated shard from a killed process is readable up to the cut —
     `read_events_prefix` — and the cut is reported per shard);
  2. with K > 1 shards, events are annotated with their source `shard`
     index and original `src_seq`, then stably merged by `t_s` — ties
     keep shard order, and a shard's internal order is always preserved
     because per-sink `t_s` is monotonic (seq-preserving per sink);
  3. the merged envelope gets a fresh gap-free global `seq` and the
     result is re-validated (`validate_events` scopes its round-ordering
     checks per shard, so interleaved per-process streams do not false-
     positive).

Merging one shard is the identity (no annotation, no renumbering) —
pinned by tests.  `t_s` is sink-relative: cross-shard interleaving is
only as aligned as the sinks' creation times, which for a multi-host
launch (all processes start together) is what a reader wants; per-shard
order is exact regardless.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Optional, Sequence

from repro.telemetry.events import (
    TelemetryError, read_events, read_events_prefix, validate_events,
)


def shard_run_ids(events) -> set:
    """The run_ids announced by a shard's run_start events."""
    return {ev.get("run_id") for ev in events if ev.get("event") == "run_start"}


def merge_streams(shards: Sequence[list], *,
                  run_id: Optional[str] = None) -> list[dict]:
    """Merge K per-process event streams into one validated stream.

    `run_id` filters to the shards that announce that run (a shared log
    directory may hold strays from other runs); with it unset, all
    shards are merged.  Raises TelemetryError when a shard fails its own
    gap-check/schema validation, when `run_id` matches no shard, or when
    the merged stream fails re-validation.
    """
    picked: list[tuple[int, list]] = []
    for i, events in enumerate(shards):
        try:
            validate_events(events)
        except TelemetryError as e:
            raise TelemetryError(f"shard {i} failed validation: {e}") from e
        if run_id is not None and run_id not in shard_run_ids(events):
            continue
        picked.append((i, events))
    if not picked:
        raise TelemetryError(
            f"no shard announces run_id {run_id!r} "
            f"(searched {len(shards)} shards)")
    if len(picked) == 1:
        return list(picked[0][1])

    annotated = []
    for i, events in picked:
        for ev in events:
            rec = dict(ev)
            rec["shard"] = i
            rec["src_seq"] = ev["seq"]
            annotated.append(rec)
    annotated.sort(key=lambda ev: ev["t_s"])   # stable: ties keep shard order
    for seq, rec in enumerate(annotated):
        rec["seq"] = seq
    validate_events(annotated)
    return annotated


def merge_files(paths: Sequence[str], *, run_id: Optional[str] = None,
                tolerate_truncation: bool = True
                ) -> tuple[list[dict], list[dict]]:
    """Read, gap-check, and merge shard files.

    Returns `(merged_events, shard_reports)`; each report records the
    shard's path, event count, and — when `tolerate_truncation` let a
    killed process's shard load as a prefix — where the cut was.
    """
    shards, reports = [], []
    for p in paths:
        if tolerate_truncation:
            events, cut = read_events_prefix(p)
        else:
            events, cut = read_events(p), None
        shards.append(events)
        reports.append({"path": p, "events": len(events), "cut": cut})
    return merge_streams(shards, run_id=run_id), reports


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("paths", nargs="+", help="per-process JSONL shards")
    ap.add_argument("-o", "--out", default=None,
                    help="merged JSONL output path (default: stdout)")
    ap.add_argument("--run-id", default=None,
                    help="merge only shards announcing this run_id")
    ap.add_argument("--strict", action="store_true",
                    help="refuse truncated shards instead of merging "
                         "their readable prefix")
    args = ap.parse_args(argv)

    try:
        merged, reports = merge_files(args.paths, run_id=args.run_id,
                                      tolerate_truncation=not args.strict)
    except (TelemetryError, ValueError, OSError) as e:
        print(f"merge failed: {e}", file=sys.stderr)
        return 1
    for rep in reports:
        note = (f" (truncated at line {rep['cut']['line']})"
                if rep["cut"] else "")
        print(f"# shard {rep['path']}: {rep['events']} events{note}",
              file=sys.stderr)
    print(f"# merged {len(reports)} shards -> {len(merged)} events "
          "(validated)", file=sys.stderr)
    out = open(args.out, "w") if args.out else sys.stdout
    try:
        for ev in merged:
            json.dump(ev, out)
            out.write("\n")
    finally:
        if args.out:
            out.close()
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""Stage tracing: profiler annotation, compile-time split, live tap.

Three tools, all bit-neutral by construction (DESIGN.md §15):

  * `stage(name)` / `named_stage(name)` — stage annotation.  `stage` is a
    host-side `jax.profiler.TraceAnnotation` context (shows up as a named
    span on the profiler timeline around a dispatch); `named_stage` is the
    in-trace `jax.named_scope` (names the HLO ops of a region, so profiles
    of the fused scan attribute time to select/train/shapley/aggregate/
    eval instead of one opaque dispatch).  Both are pure metadata.

  * `CompileTimer` — attributes jit compilation via `jax.monitoring`
    duration events (`/jax/core/compile/...`: trace, MLIR lowering,
    backend compile).  A module-level listener fans durations into every
    active timer, so `FLResult.wall_time_s` can be split into
    compile vs execute without re-dispatching or AOT double-compiles.
    Warm executables emit no events, so a cached run reports ~0 compile.

  * the live tap — an *opt-in* `jax.debug.callback` planted in the scan
    body (`ScanSpec.live_tap`, round_engine.py) that streams `round_tap`
    events while the one-dispatch scan is still executing.  The host side
    here is a process-global sink set around the dispatch
    (`live_sink(...)`); the callback routes to it.  Caveats (§15): the
    tap recompiles the scan (callbacks are part of the trace), events may
    arrive out of round order (`ordered=False`), and under the replica
    vmap the callback fires per replica WITHOUT a cell index — per-cell
    attribution is the job of the host-side segment-boundary aggregation,
    the tap is a liveness/diagnostics stream.  It must stay bit-neutral;
    tests/test_telemetry.py pins selections/params/evals across
    off / host-side / live-tap.
"""
from __future__ import annotations

import contextlib
import threading
import time
from typing import Iterator, Optional

import jax

# stage names used by the engines; kernels/profiles key off these
STAGES = ("select", "train", "shapley", "aggregate", "eval")

# prefix every host/trace span carries; profile.py recovers per-stage
# wall time by summing spans with this prefix out of a capture window
SPAN_PREFIX = "repro."


class SpanRecorder:
    """Host-side record of `stage()` spans: name -> total wall seconds.

    Installed by `record_spans()` (profile.trace_capture uses it as the
    always-available fallback when the profiler's trace files cannot be
    parsed) — `stage()` adds its wall duration here whenever a recorder
    is active."""

    def __init__(self) -> None:
        self.spans: list[tuple[str, float]] = []

    def add(self, name: str, seconds: float) -> None:
        self.spans.append((name, seconds))

    def totals(self) -> dict[str, float]:
        out: dict[str, float] = {}
        for name, secs in self.spans:
            out[name] = out.get(name, 0.0) + secs
        return out


_span_recorder: Optional[SpanRecorder] = None


@contextlib.contextmanager
def record_spans() -> Iterator[SpanRecorder]:
    """Install a SpanRecorder for the enclosed region (re-entrant: an
    inner recorder shadows the outer one for its extent)."""
    global _span_recorder
    prev = _span_recorder
    rec = SpanRecorder()
    _span_recorder = rec
    try:
        yield rec
    finally:
        _span_recorder = prev


@contextlib.contextmanager
def stage(name: str) -> Iterator[None]:
    """Host-side profiler span around a region of dispatches."""
    rec = _span_recorder
    t0 = time.perf_counter() if rec is not None else 0.0
    with jax.profiler.TraceAnnotation(f"{SPAN_PREFIX}{name}"):
        yield
    if rec is not None:
        rec.add(name, time.perf_counter() - t0)


def named_stage(name: str):
    """In-trace scope: names the HLO of a region (zero-cost metadata)."""
    return jax.named_scope(f"repro.{name}")


# ---- compile-time attribution (jax.monitoring) ---------------------------

_COMPILE_EVENT_PREFIX = "/jax/core/compile"
_active_timers: list["CompileTimer"] = []
_listener_lock = threading.Lock()
_listener_registered = False


def _on_duration(key: str, seconds: float, **_kw) -> None:
    if key.startswith(_COMPILE_EVENT_PREFIX):
        for t in _active_timers:
            t.seconds += seconds


def _ensure_listener() -> None:
    global _listener_registered
    with _listener_lock:
        if not _listener_registered:
            try:
                jax.monitoring.register_event_duration_secs_listener(
                    _on_duration)
            except AttributeError:   # very old jax: no monitoring API
                pass
            _listener_registered = True


class CompileTimer:
    """Accumulates jit trace+lower+compile seconds while active.

    Re-enterable: one timer may wrap several regions of the same run
    (setup, then the dispatch), accumulating into `.seconds`.  Nesting
    two different timers double-counts nothing per timer — each active
    timer sees every compile in its own window, which is exactly the
    "how much of THIS run's wall time was compilation" question.
    """

    def __init__(self) -> None:
        self.seconds = 0.0

    def __enter__(self) -> "CompileTimer":
        _ensure_listener()
        _active_timers.append(self)
        return self

    def __exit__(self, *exc) -> None:
        _active_timers.remove(self)


# ---- the in-scan live tap ------------------------------------------------

_live_sink = None


@contextlib.contextmanager
def live_sink(telemetry) -> Iterator[None]:
    """Route `round_tap` callbacks to `telemetry` for the enclosed
    dispatch.  The caller must block on the dispatch's outputs before
    leaving the context so in-flight callbacks have landed."""
    global _live_sink
    prev = _live_sink
    _live_sink = telemetry
    try:
        yield
    finally:
        _live_sink = prev


def round_tap(t, strategy_id, sel, sv, utility_evals, sv_truncated) -> None:
    """The `jax.debug.callback` target planted by `ScanSpec.live_tap`.

    Fires once per round (per replica under the grid vmap) with that
    round's device values; a no-op unless a sink is installed, so a
    tap-compiled executable is safe to reuse without telemetry.
    """
    tel = _live_sink
    if tel is None:
        return
    tel.emit("round_tap", round=t, origin="device",
             strategy_id=strategy_id, selections=sel, sv=sv,
             utility_evals=utility_evals, sv_truncated=sv_truncated)


def attach_live_tap(t, strategy_id, sel, sv, utility_evals,
                    sv_truncated) -> None:
    """Plant the tap in a traced scan body (round_engine calls this)."""
    jax.debug.callback(round_tap, t, strategy_id, sel, sv, utility_evals,
                       sv_truncated, ordered=False)

"""Bench-regression tier: diff BENCH_*.json against committed baselines.

    PYTHONPATH=src python -m repro.telemetry.regress [--bench-dir .]
        [--baselines benchmarks/baselines] [--trajectory BENCH_trajectory.json]

Every benchmark artifact in the repo root is provenance-stamped
(events.write_bench_json) but nothing *watched* them — a PR could double
the hot path's latency and the six BENCH files would silently record it.
This module is the watcher:

  * `WATCHED` names, per bench schema, the metrics that constitute the
    perf contract — dotted paths (list indices allowed), a direction,
    and a tolerance band.  Relative bands absorb CPU-box timing noise
    (latencies get wide bands, compiled flops/bytes get tight ones,
    counters get zero); absolute bands serve near-zero metrics like the
    telemetry overhead percentage where a ratio is meaningless.
  * `compare_bench` evaluates one current-vs-baseline pair; `run_check`
    sweeps every BENCH_*.json with a registered schema, appends one
    provenance-stamped entry to the `BENCH_trajectory.json` ledger
    (pass or fail — the trajectory records history, it is not a trophy
    case), and reports regressions.
  * the CLI exits nonzero on any regression, so `CHECK_BENCH_TREND=1
    scripts/check.sh` (`make bench-check`) turns the passive artifacts
    into a gate.  `--seed` copies the current artifacts into the
    baseline directory (how `benchmarks/baselines/` was first populated).

Baselines live in git (`benchmarks/baselines/`), so the diff is always
against what the last accepted PR shipped, not against a moving box.
A schema-tag mismatch between current and baseline marks the pair
`incomparable` (skipped, reported) — re-seed after an intentional
format change.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import re
import sys
from typing import NamedTuple, Optional

from repro.telemetry.events import provenance

TRAJECTORY_SCHEMA = "bench_trajectory/v1"


class Metric(NamedTuple):
    """One watched metric: where it lives and how far it may drift."""
    path: str                    # dotted path, list indices as [i]
    direction: str               # "lower" | "higher" (which way is better)
    rel_tol: Optional[float] = None   # band as a fraction of baseline
    abs_tol: Optional[float] = None   # band in the metric's own units


# the perf contract per bench schema.  Latency bands are wide (CPU smoke
# timings breathe ~tens of percent between boxes); compiled-cost and
# byte-accounting bands are tight (deterministic); dispatch counts are
# exact — a dispatch-count regression is a structural bug, not noise.
WATCHED: dict[str, tuple] = {
    "bench_selection/v1": (
        Metric("e2e_greedyfed.scan.us_per_round", "lower", rel_tol=0.75),
        Metric("e2e_greedyfed.batched.us_per_round", "lower", rel_tol=0.75),
        Metric("e2e_greedyfed.scan.dispatches_total", "lower", rel_tol=0.0),
        Metric("e2e_greedyfed.batched.dispatches_per_round", "lower",
               rel_tol=0.0),
        Metric("speedup.scan_vs_loop_e2e", "higher", rel_tol=0.5),
    ),
    "bench_shapley/v1": (
        Metric("latency_us.streaming", "lower", rel_tol=0.75),
        Metric("compiled_flops.streaming_e2e", "lower", rel_tol=0.10),
        Metric("compiled_flops.construction_reduction", "higher",
               rel_tol=0.10),
        Metric("peak_model_bytes_estimate.streaming_auto_off_tpu", "lower",
               rel_tol=0.10),
        Metric("speedup_streaming_vs_dense", "higher", rel_tol=0.5),
    ),
    "bench_grid/v1": (
        Metric("segment_latency_us", "lower", rel_tol=0.75),
        Metric("bytes_resident_per_device", "lower", rel_tol=0.10),
        Metric("partitions[0].dispatches", "lower", rel_tol=0.0),
        Metric("sv_partition_skipped_in_plain.plain_partition_shapley_evals",
               "lower", rel_tol=0.0),
    ),
    "bench_telemetry/v1": (
        Metric("e2e_us.off", "lower", rel_tol=0.75),
        # host-side overhead is ~0% by contract; a ratio band around it
        # is meaningless, so the band is 3 percentage points absolute
        Metric("overhead_pct.host", "lower", abs_tol=3.0),
    ),
    "bench_clients/v1": (
        Metric("rows[0].sharded.per_device_state_bytes", "lower",
               rel_tol=0.10),
        Metric("rows[0].dense_over_sharded_per_device_bytes", "higher",
               rel_tol=0.10),
        Metric("memory_analysis.sharded.peak_bytes", "lower", rel_tol=0.25),
    ),
    "bench_comm/v2": (
        # compiled cost of the fused codec roundtrip (the in-scan upload
        # path): deterministic, so the bands are tight — and the fused
        # path must KEEP its bytes/flops advantage over the tree-map ref
        Metric("codec_roundtrip.quant8.fused.bytes_accessed", "lower",
               rel_tol=0.10),
        Metric("codec_roundtrip.quant8.fused.flops", "lower", rel_tol=0.10),
        Metric("codec_roundtrip.quant8.ref_over_fused_bytes_accessed",
               "higher", rel_tol=0.10),
        # the §18 partition collapse is structural: executable/dispatch
        # counts for the strategies x codecs grid are exact
        Metric("grid.executables", "lower", rel_tol=0.0),
        Metric("grid.dispatches", "lower", rel_tol=0.0),
        Metric("pareto[0].acc_mean", "higher", abs_tol=0.10),
    ),
    "bench_faults/v1": (
        # quarantine counts under a fixed fault table are deterministic
        # (the table is pre-drawn from the config seed): pin them EXACTLY
        # by pairing a zero-band "lower" with a zero-band "higher" — any
        # drift in either direction is a screen-semantics change, not
        # noise.  Keys use "rate20"/"rate50" (no dots: the path grammar
        # splits on ".").
        Metric("quarantine_counts.rate20.greedyfed", "lower", rel_tol=0.0),
        Metric("quarantine_counts.rate20.greedyfed", "higher", rel_tol=0.0),
        Metric("quarantine_counts.rate50.greedyfed", "lower", rel_tol=0.0),
        Metric("quarantine_counts.rate50.greedyfed", "higher", rel_tol=0.0),
        # hardened-path overhead: wide latency band (CPU smoke timing)
        Metric("overhead.us_per_round_on", "lower", rel_tol=0.75),
    ),
}

_PATH_TOKEN = re.compile(r"([^.\[\]]+)|\[(\d+)\]")


def lookup(obj, path: str):
    """Resolve a dotted/indexed path; None when any hop is missing."""
    cur = obj
    for m in _PATH_TOKEN.finditer(path):
        key, idx = m.group(1), m.group(2)
        try:
            cur = cur[key] if key is not None else cur[int(idx)]
        except (KeyError, IndexError, TypeError):
            return None
    return cur


def check_metric(metric: Metric, current, baseline) -> dict:
    """Evaluate one metric pair into a trajectory record."""
    cur = lookup(current, metric.path)
    base = lookup(baseline, metric.path)
    rec = {"path": metric.path, "direction": metric.direction,
           "current": cur, "baseline": base}
    if not isinstance(cur, (int, float)) or not isinstance(
            base, (int, float)) or isinstance(cur, bool) or isinstance(
            base, bool):
        rec["status"] = "missing"
        return rec
    if metric.abs_tol is not None:
        band = metric.abs_tol
    else:
        band = abs(base) * (metric.rel_tol or 0.0)
    if metric.direction == "lower":
        bound = base + band
        ok = cur <= bound
    else:
        bound = base - band
        ok = cur >= bound
    rec.update(bound=bound, status="ok" if ok else "regressed")
    if base:
        rec["ratio"] = cur / base
    return rec


def compare_bench(schema: str, current: dict, baseline: dict) -> list[dict]:
    """All watched-metric records for one bench pair."""
    return [check_metric(m, current, baseline)
            for m in WATCHED.get(schema, ())]


def _load(path: str) -> Optional[dict]:
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, ValueError):
        return None


def run_check(bench_dir: str, baseline_dir: str,
              trajectory_path: Optional[str]) -> dict:
    """Sweep every BENCH_*.json in `bench_dir` against `baseline_dir`.

    Returns the trajectory entry (status, per-bench metric records,
    notes for anything skipped); when `trajectory_path` is set the entry
    is appended to that provenance-stamped ledger regardless of outcome.
    """
    benches: dict[str, dict] = {}
    notes: list[str] = []
    n_regressed = n_checked = 0
    paths = sorted(glob.glob(os.path.join(bench_dir, "BENCH_*.json")))
    for path in paths:
        name = os.path.basename(path)
        if name == os.path.basename(trajectory_path or "BENCH_trajectory.json"):
            continue
        current = _load(path)
        if current is None:
            notes.append(f"{name}: unreadable, skipped")
            continue
        schema = current.get("schema")
        if schema not in WATCHED:
            notes.append(f"{name}: schema {schema!r} has no watched "
                         "metrics, skipped")
            continue
        base_path = os.path.join(baseline_dir, name)
        baseline = _load(base_path)
        if baseline is None:
            notes.append(f"{name}: no baseline at {base_path}, skipped "
                         "(seed with --seed)")
            continue
        if baseline.get("schema") != schema:
            notes.append(f"{name}: schema changed "
                         f"({baseline.get('schema')!r} -> {schema!r}), "
                         "incomparable — re-seed the baseline")
            continue
        metrics = compare_bench(schema, current, baseline)
        benches[name] = {
            "schema": schema,
            "baseline_rev": (baseline.get("provenance") or {}).get("git_rev"),
            "metrics": metrics,
        }
        n_checked += sum(m["status"] != "missing" for m in metrics)
        n_regressed += sum(m["status"] == "regressed" for m in metrics)

    prov = provenance()
    entry = {
        "timestamp": prov["timestamp"],
        "git_rev": prov["git_rev"],
        "backend": prov["backend"],
        "status": "regressed" if n_regressed else "pass",
        "metrics_checked": n_checked,
        "metrics_regressed": n_regressed,
        "benches": benches,
        "notes": notes,
    }
    if trajectory_path:
        append_trajectory(trajectory_path, entry)
    return entry


def append_trajectory(path: str, entry: dict) -> None:
    """Append one entry to the BENCH_trajectory.json ledger."""
    from repro.telemetry.events import write_bench_json

    ledger = _load(path) or {"schema": TRAJECTORY_SCHEMA, "entries": []}
    if ledger.get("schema") != TRAJECTORY_SCHEMA:
        raise ValueError(f"{path} is not a {TRAJECTORY_SCHEMA} ledger "
                         f"(schema={ledger.get('schema')!r})")
    ledger.setdefault("entries", []).append(entry)
    write_bench_json(path, ledger)


def seed_baselines(bench_dir: str, baseline_dir: str) -> list[str]:
    """Copy the current BENCH_*.json artifacts into the baseline dir."""
    import shutil

    os.makedirs(baseline_dir, exist_ok=True)
    seeded = []
    for path in sorted(glob.glob(os.path.join(bench_dir, "BENCH_*.json"))):
        name = os.path.basename(path)
        if name == "BENCH_trajectory.json":
            continue
        if (_load(path) or {}).get("schema") not in WATCHED:
            continue
        shutil.copy(path, os.path.join(baseline_dir, name))
        seeded.append(name)
    return seeded


def render(entry: dict) -> str:
    lines = []
    for name, bench in sorted(entry["benches"].items()):
        for m in bench["metrics"]:
            mark = {"ok": " ok ", "regressed": "FAIL",
                    "missing": "skip"}[m["status"]]
            cur, base = m["current"], m["baseline"]
            ratio = f" ({m['ratio']:.2f}x)" if "ratio" in m else ""
            lines.append(f"[{mark}] {name}:{m['path']} "
                         f"{m['direction']}-is-better "
                         f"current={cur} baseline={base}{ratio}")
    for note in entry["notes"]:
        lines.append(f"[note] {note}")
    lines.append(f"checked {entry['metrics_checked']} metrics, "
                 f"{entry['metrics_regressed']} regressed -> "
                 f"{entry['status'].upper()}")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--bench-dir", default=".",
                    help="directory holding the current BENCH_*.json")
    ap.add_argument("--baselines", default="benchmarks/baselines",
                    help="committed baseline directory")
    ap.add_argument("--trajectory", default=None,
                    help="trajectory ledger path (default: "
                         "<bench-dir>/BENCH_trajectory.json; 'none' "
                         "disables the append)")
    ap.add_argument("--seed", action="store_true",
                    help="copy current artifacts into the baseline dir "
                         "instead of checking")
    args = ap.parse_args(argv)

    if args.seed:
        seeded = seed_baselines(args.bench_dir, args.baselines)
        print(f"seeded {len(seeded)} baselines into {args.baselines}: "
              f"{', '.join(seeded)}")
        return 0

    trajectory = args.trajectory
    if trajectory is None:
        trajectory = os.path.join(args.bench_dir, "BENCH_trajectory.json")
    elif trajectory == "none":
        trajectory = None
    entry = run_check(args.bench_dir, args.baselines, trajectory)
    print(render(entry))
    if trajectory:
        print(f"# trajectory -> {trajectory}")
    return 1 if entry["status"] == "regressed" else 0


if __name__ == "__main__":
    sys.exit(main())

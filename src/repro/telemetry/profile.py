"""Device-cost profiling: per-executable cost cards + trace capture.

The PR-6 event stream records *when* things happened; this module records
*what they cost* (DESIGN.md §17).  Two tools:

  * `cost_card(jitted, *args)` — one AOT lower+compile, three probes
    unified (launch.compat `cost_analysis_of` / `memory_stats_of` plus
    the roofline terms of launch.roofline): compiled flops, bytes
    accessed, the XLA memory-analysis byte classes with derived
    `peak_bytes`, arithmetic intensity (flops / bytes accessed), and the
    v5e-normalised roofline split (compute-bound vs memory-bound seconds;
    `cost_analysis()` runs on the post-SPMD module, so every figure is
    per-device).  `cached_cost_card` memoises by (executable, arg avals)
    — the engines call it on every run but a warm executable re-pays
    nothing, keeping the BENCH_telemetry host-overhead gate honest.
    Engines attach the card to their `compile` telemetry events, so the
    JSONL stream answers "which stage burns the flops/bytes" without a
    profiler in the loop.

  * `trace_capture(telemetry, label)` — the opt-in programmatic
    `jax.profiler.start_trace`/`stop_trace` window (`Telemetry(trace_dir=
    ...)`): artifacts land in `<trace_dir>/<run_id>/`, and on exit a
    `profile` event reports per-stage wall seconds recovered from the
    §15 `TraceAnnotation` spans — parsed out of the profiler's Chrome-
    trace export when the backend wrote one (`source="trace"`), else
    from the host-side `SpanRecorder` fallback (`source="host"`).  The
    in-scan `named_scope` stages additionally name the HLO regions for
    device timelines (TPU); the capture window is how those profiles
    get collected.  Nested/concurrent captures degrade gracefully: if
    the profiler is already tracing, the window falls back to host-span
    attribution instead of raising.

Everything here is observation-only: no extra device dispatches, and a
telemetry-off run never reaches this module.
"""
from __future__ import annotations

import contextlib
import glob
import gzip
import json
import os
from typing import Any, Iterator, Optional

import jax

from repro.launch.compat import aot_compile, cost_analysis_of, memory_stats_of
from repro.telemetry.trace import SPAN_PREFIX, record_spans

# v5e roofline constants (launch.roofline is the source of truth); the
# card's roofline block normalises per-device cost against this target
# part even off-TPU, so trajectory comparisons are hardware-stable.
from repro.launch.roofline import HBM_BW, PEAK_FLOPS


def cost_card_of_compiled(compiled) -> Optional[dict]:
    """Assemble a cost card from an already-compiled executable."""
    card: dict = dict(cost_analysis_of(compiled))
    mem = memory_stats_of(compiled)
    if mem:
        card.update(mem)
    if not card:
        return None
    flops = card.get("flops")
    bytes_acc = card.get("bytes_accessed")
    if flops is not None and bytes_acc:
        card["intensity_flops_per_byte"] = flops / bytes_acc
    if flops is not None or bytes_acc is not None:
        compute_s = (flops or 0.0) / PEAK_FLOPS
        memory_s = (bytes_acc or 0.0) / HBM_BW
        card["roofline"] = {
            "compute_s": compute_s,
            "memory_s": memory_s,
            "dominant": "compute" if compute_s >= memory_s else "memory",
            "ridge_intensity_flops_per_byte": PEAK_FLOPS / HBM_BW,
        }
    return card


def cost_card(jitted, *args, **kwargs) -> Optional[dict]:
    """One lower+compile, every cost probe: the per-executable cost card
    for `jitted` at these args (avals only — donated buffers are safe).
    None when the backend exposes no analysis at all."""
    compiled = aot_compile(jitted, *args, **kwargs)
    if compiled is None:
        return None
    return cost_card_of_compiled(compiled)


# (jitted, arg-aval signature) -> card.  Keys hold strong references,
# which is what we want: the engines' jitted callables are process-wide
# lru-cached anyway (round_engine), so entries are few and long-lived.
_CARD_CACHE: dict = {}


def _aval_sig(args, kwargs):
    leaves, treedef = jax.tree.flatten((args, kwargs))
    return (treedef, tuple(
        (leaf.shape, str(leaf.dtype)) if hasattr(leaf, "shape")
        and hasattr(leaf, "dtype") else repr(leaf) for leaf in leaves))


def cached_cost_card(jitted, *args, **kwargs) -> Optional[dict]:
    """`cost_card` memoised on (executable, arg shapes/dtypes).

    The AOT probe costs a fresh lower+compile on first sight of a shape;
    every later call (reruns, bench reps, further segments of the same
    grid) is a dict lookup.  A None result is cached too — a backend
    without analysis shouldn't re-pay the failed compile each round.
    """
    try:
        key = (jitted, _aval_sig(args, kwargs))
        hash(key)
    except TypeError:
        return cost_card(jitted, *args, **kwargs)
    if key not in _CARD_CACHE:
        _CARD_CACHE[key] = cost_card(jitted, *args, **kwargs)
    return _CARD_CACHE[key]


# ---- the capture window --------------------------------------------------

def stage_wall_from_trace(trace_dir: str) -> Optional[dict]:
    """Per-stage wall seconds from a profiler capture's Chrome trace.

    `jax.profiler.stop_trace` exports `plugins/profile/<ts>/*.trace.json
    .gz`; the §15 `TraceAnnotation` spans appear there as complete events
    named `repro.<stage>` with microsecond durations.  Returns
    {stage: seconds} summed over all matching spans (newest capture under
    `trace_dir` wins), or None when no parseable trace exists — the
    caller then falls back to host-side span timing.  `named_scope`
    stages annotate device-op timelines instead and stay in the artifact
    for offline viewers.
    """
    paths = sorted(glob.glob(os.path.join(
        trace_dir, "plugins", "profile", "*", "*.trace.json.gz")))
    if not paths:
        return None
    try:
        with gzip.open(paths[-1], "rt") as f:
            trace = json.load(f)
        walls: dict[str, float] = {}
        for ev in trace.get("traceEvents", []):
            name = ev.get("name", "")
            if ev.get("ph") == "X" and name.startswith(SPAN_PREFIX):
                stage = name[len(SPAN_PREFIX):]
                walls[stage] = walls.get(stage, 0.0) + \
                    float(ev.get("dur", 0.0)) / 1e6
        return walls or None
    except Exception:
        return None


@contextlib.contextmanager
def trace_capture(telemetry, label: str = "run") -> Iterator[Any]:
    """Profiler capture window around a run's dispatches (opt-in).

    No-op (yields None) unless `telemetry` carries a `trace_dir`.  Active
    windows start `jax.profiler.start_trace` into the run_id-stamped
    directory, record host `stage()` spans, and on exit stop the trace
    and emit one `profile` event: where the artifacts are, per-stage wall
    seconds, and which recovery source produced them.  The caller must
    block on its dispatches inside the window (the engines do) so spans
    cover execution, not enqueue.
    """
    if telemetry is None or not getattr(telemetry, "trace_dir", None):
        yield None
        return
    tdir = os.path.join(telemetry.trace_dir, telemetry.run_id)
    started = False
    try:
        jax.profiler.start_trace(tdir)
        started = True
    except Exception:
        pass   # profiler already tracing / unavailable: host spans only
    try:
        with record_spans() as rec:
            yield rec
    finally:
        if started:
            try:
                jax.profiler.stop_trace()
            except Exception:
                started = False
        walls = stage_wall_from_trace(tdir) if started else None
        source = "trace" if walls else "host"
        telemetry.emit("profile", trace_dir=tdir, label=label,
                       captured=started, source=source,
                       stage_wall_s=walls or rec.totals())

"""Pre-drawn (T, N) fault-code tables on the frozen host rng stream.

The table is drawn once in `setup_run` — strictly AFTER every existing
draw and gated on `cfg.faults is not None`, so fault-free configs keep
a bitwise-identical rng stream (same discipline as the straggler_rev=1
epochs table, DESIGN.md §9).  All three engines then *read* the same
table: the loop engine indexes it on the host, the scan engines thread
it as a per-round operand row.
"""
from __future__ import annotations

import numpy as np

from repro.faults.spec import FAULT_CODES, FaultSpec


def draw_fault_table(spec: FaultSpec, rounds: int, n_clients: int,
                     rng: np.random.Generator) -> np.ndarray:
    """(rounds, n_clients) int32 fault codes; 0 = honest.

    Two rng draws per table (fire mask, kind choice) regardless of how
    many entries actually fire, so the stream position depends only on
    the table shape — never on the fault outcome.
    """
    spec.validate()
    codes = np.asarray([FAULT_CODES[k] for k in spec.kinds], np.int32)
    fire = rng.random((rounds, n_clients)) < spec.rate
    idx = rng.integers(0, len(codes), size=(rounds, n_clients))
    table = np.where(fire, codes[idx], 0).astype(np.int32)
    if spec.start_round > 0:
        table[: spec.start_round] = 0
    return table

"""Cohort hardening: fault injection, the quarantine screen, masked SV
weights, and masked aggregation — one pure traceable pipeline shared by
every engine (DESIGN.md §19).

Identity contract: with `faults is None` and `quarantine False`,
`harden_cohort` is a static passthrough (zero ops).  With the screen ON
over a clean cohort, every mask is all-True and each `jnp.where` is an
elementwise bitwise identity, so quarantine-on-clean == quarantine-off
bitwise (pinned in tests/test_faults.py).

SV-masking scheme: quarantined rows are substituted with the previous
global params (delta == 0) and given the weight TINY_WEIGHT = 2^-100.
In f32 accumulation TINY_WEIGHT is exactly absorbed by any honest
weight >= 1, so prefix averages over honest prefixes are bitwise as if
the quarantined row were absent, while all-masked prefixes degenerate
to w_prev (utility == the round's v0) rather than NaN.  Post-hoc the
quarantined SV entries are zeroed.  No prefix kernel changes needed.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.aggregation import normalized_weights, weighted_average
from repro.faults.spec import (
    CODE_CRASH, CODE_INF, CODE_NAN, CODE_NONE, CODE_SCALE, CODE_SIGN_FLIP,
    FaultSpec,
)

# smallest "still participating" SV weight: exactly absorbed (f32) when
# any honest weight >= 1 shares the prefix, yet keeps all-masked
# prefixes well-defined (average == w_prev) instead of 0/0 NaN
TINY_WEIGHT = 2.0 ** -100


class HardenedCohort(NamedTuple):
    stacked: Any          # cohort updates, quarantined rows := w_prev
    n_k_agg: jax.Array    # (M,) aggregation weights, quarantined := 0
    n_k_sv: jax.Array     # (M,) SV-walk weights, quarantined := TINY_WEIGHT
    ok: jax.Array         # (M,) bool — survived injection + screen
    quarantined: jax.Array  # () int32 count of masked rows


def _per_row(a: jax.Array, like: jax.Array) -> jax.Array:
    """Broadcast an (M,) vector against an (M, ...) stacked leaf."""
    return a.reshape((-1,) + (1,) * (like.ndim - 1))


def apply_faults(stacked, params, codes: jax.Array, scale: float):
    """Inject the coded faults into a stacked cohort of client params.

    codes is the (M,) int32 gather of the fault table at the selected
    clients.  Code-0 (and CRASH — payload intact, masked later) rows
    pass through bitwise untouched: the guard matters because even
    `p + (w - p) * 1.0` is not bitwise `w` in f32.
    """

    def leaf(w, p):
        c = _per_row(codes, w)
        d = w - p[None]
        factor = jnp.where(c == CODE_SIGN_FLIP, -scale,
                           jnp.where(c == CODE_SCALE, scale, 1.0)).astype(w.dtype)
        faulty = p[None] + d * factor
        faulty = jnp.where(c == CODE_NAN, jnp.asarray(jnp.nan, w.dtype), faulty)
        faulty = jnp.where(c == CODE_INF, jnp.asarray(jnp.inf, w.dtype), faulty)
        untouched = (c == CODE_NONE) | (c == CODE_CRASH)
        return jnp.where(untouched, w, faulty)

    return jax.tree.map(leaf, stacked, params)


def screen_cohort(stacked, params, *, z: float,
                  rel_floor: float = 0.1) -> jax.Array:
    """(M,) bool quarantine screen over decoded cohort deltas.

    Two tests per client: every leaf entry finite, and the delta L2 norm
    under a robust cutoff `median + z * (1.4826*MAD + rel_floor*median
    + 1e-6)` computed over the *finite* norms (nanmedian).  The MAD term
    adapts to the cohort's spread; the rel_floor and epsilon terms keep
    the cutoff permissive when honest norms are tightly clustered or
    near zero.  An all-non-finite cohort yields a NaN cutoff, so every
    client fails the comparison — all quarantined, as it should be.
    Deterministic: no rng draws.
    """
    ws, ps = jax.tree.leaves(stacked), jax.tree.leaves(params)
    m = ws[0].shape[0]
    sq = jnp.zeros((m,), jnp.float32)
    finite = jnp.ones((m,), bool)
    for w, p in zip(ws, ps):
        d = (w - p[None]).reshape(m, -1).astype(jnp.float32)
        finite = finite & jnp.isfinite(d).all(axis=1)
        sq = sq + jnp.sum(d * d, axis=1)
    norm = jnp.sqrt(sq)
    masked = jnp.where(finite, norm, jnp.nan)
    med = jnp.nanmedian(masked)
    mad = jnp.nanmedian(jnp.abs(masked - med))
    cutoff = med + z * (1.4826 * mad + rel_floor * med + 1e-6)
    return finite & (norm <= cutoff)


def harden_cohort(stacked, params, n_k_sel: jax.Array, codes: jax.Array, *,
                  faults: Optional[FaultSpec], quarantine: bool,
                  z: float) -> HardenedCohort:
    """Inject + screen + mask.  Static passthrough when both are off."""
    m = n_k_sel.shape[0]
    if faults is None and not quarantine:
        return HardenedCohort(stacked, n_k_sel, n_k_sel,
                              jnp.ones((m,), bool), jnp.zeros((), jnp.int32))
    if faults is not None:
        stacked = apply_faults(stacked, params, codes, faults.scale)
        ok = codes != CODE_CRASH
    else:
        ok = jnp.ones((m,), bool)
    if quarantine:
        ok = ok & screen_cohort(stacked, params, z=z)
    quarantined = jnp.sum(jnp.logical_not(ok).astype(jnp.int32))
    # substitute masked rows with w_prev BEFORE aggregation/SV: a NaN row
    # would otherwise poison `weighted_average` through 0 * NaN = NaN
    stacked = jax.tree.map(
        lambda w, p: jnp.where(_per_row(ok, w), w, p[None]), stacked, params)
    n_k_agg = jnp.where(ok, n_k_sel, jnp.zeros((), n_k_sel.dtype))
    n_k_sv = jnp.where(ok, n_k_sel, jnp.asarray(TINY_WEIGHT, n_k_sel.dtype))
    return HardenedCohort(stacked, n_k_agg, n_k_sv, ok, quarantined)


def masked_average(stacked, n_k_agg: jax.Array, ok: jax.Array, params):
    """Aggregate the hardened cohort; an all-quarantined round keeps the
    previous global params (normalized_weights would yield a zero sum)."""
    agg = weighted_average(stacked, normalized_weights(n_k_agg))
    any_ok = jnp.any(ok)
    return jax.tree.map(lambda a, p: jnp.where(any_ok, a, p), agg, params)


@functools.lru_cache(maxsize=16)
def _jitted_harden_cached(faults: Optional[FaultSpec], quarantine: bool,
                          z: float):
    return jax.jit(functools.partial(
        harden_cohort, faults=faults, quarantine=quarantine, z=z))


def jitted_harden(faults: Optional[FaultSpec], quarantine: bool, z: float):
    """Cached jitted `harden_cohort` for the host loop engine, so every
    engine runs the exact same hardening ops."""
    return _jitted_harden_cached(faults, quarantine, z)

"""repro.faults — deterministic fault injection + cohort hardening.

DESIGN.md §19.  A `FaultSpec` declares client-level faults (NaN/Inf
updates, sign-flip/scaled byzantine updates, mid-round crash ⇒ dropout)
that `setup_run` pre-draws into a (T, N) int32 code table on the frozen
host rng stream — the same pattern as the `straggler_rev=1` epochs
table — so loop/batched/scan engines consume identical fault streams.
`harden_cohort` is the shared in-round stage: inject faults into the
trained cohort, screen the decoded deltas (finite-check + robust
median/MAD norm cutoff), and mask quarantined clients out of
aggregation, the byte ledger, and the SV walks.
"""
from repro.faults.spec import (
    CODE_CRASH, CODE_INF, CODE_NAN, CODE_NONE, CODE_SCALE, CODE_SIGN_FLIP,
    FAULT_CODES, FAULT_KINDS, FaultSpec,
)
from repro.faults.table import draw_fault_table
from repro.faults.quarantine import (
    HardenedCohort, TINY_WEIGHT, apply_faults, harden_cohort, jitted_harden,
    masked_average, screen_cohort,
)

__all__ = [
    "CODE_CRASH", "CODE_INF", "CODE_NAN", "CODE_NONE", "CODE_SCALE",
    "CODE_SIGN_FLIP", "FAULT_CODES", "FAULT_KINDS", "FaultSpec",
    "HardenedCohort", "TINY_WEIGHT", "apply_faults", "draw_fault_table",
    "harden_cohort", "jitted_harden", "masked_average", "screen_cohort",
]

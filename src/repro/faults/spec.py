"""Fault model declaration: kinds, codes, and the hashable FaultSpec.

Fault *codes* are the on-device representation: a (T, N) int32 table
where 0 means "honest" and each nonzero code names one client-level
fault for that (round, client) pair.  Codes are part of the checkpoint
/ telemetry contract — never renumber, only append.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

# code 0 is reserved for "no fault"; the table draw maps kind names to
# these codes.  CRASH is a systems fault (client never reports back ⇒
# dropout: its update is bitwise untouched but masked from aggregation
# and the byte ledger); the rest corrupt the update payload itself.
CODE_NONE, CODE_NAN, CODE_INF, CODE_SIGN_FLIP, CODE_SCALE, CODE_CRASH = range(6)

FAULT_KINDS: Tuple[str, ...] = ("nan", "inf", "sign_flip", "scale", "crash")
FAULT_CODES = {
    "nan": CODE_NAN,
    "inf": CODE_INF,
    "sign_flip": CODE_SIGN_FLIP,
    "scale": CODE_SCALE,
    "crash": CODE_CRASH,
}


class FaultSpec(NamedTuple):
    """Declarative, seeded client-fault injection.

    A NamedTuple (not a dataclass) so it is hashable and can ride inside
    the static RoundSpec/ScanSpec jit keys and the grid STATIC_FIELDS
    fingerprint unchanged.

    rate          per-(round, client) probability that a fault fires
    kinds         which faults to draw from, uniformly, when one fires
    scale         magnitude for "scale" (delta * scale) and "sign_flip"
                  (delta * -scale) byzantine updates
    start_round   faults only fire from this round on (lets convergence
                  establish before the chaos begins)
    """

    rate: float = 0.1
    kinds: Tuple[str, ...] = ("nan", "sign_flip", "crash")
    scale: float = 10.0
    start_round: int = 0

    def validate(self) -> "FaultSpec":
        unknown = [k for k in self.kinds if k not in FAULT_CODES]
        if unknown:
            raise ValueError(
                f"unknown fault kinds {unknown}; known: {FAULT_KINDS}")
        if not self.kinds:
            raise ValueError("FaultSpec.kinds must name at least one kind")
        if not 0.0 <= self.rate <= 1.0:
            raise ValueError(f"FaultSpec.rate must be in [0, 1], got {self.rate}")
        if self.start_round < 0:
            raise ValueError(f"FaultSpec.start_round must be >= 0, got "
                             f"{self.start_round}")
        return self

"""run_grid — the experiment-grid executor (DESIGN.md §12).

Pipeline: validate the GridSpec -> set up every cell (same rng/key
streams as a solo run at that cell's config) -> partition cells by
capability -> per partition, stack the replica operands, place them on
the replica mesh, and drive the segmented scan -> rebuild per-cell
FLResults and re-interleave them into grid order.
"""
from __future__ import annotations

import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregation import tree_stack
from repro.core.selection_jax import poc_d_schedule
from repro.engine.round_engine import SegmentCarry
from repro.engine.schedule import eval_mask
from repro.grid.partition import (
    Partition, PartitionReport, interleave, partition_cells,
)
from repro.grid.segments import ReplicaBatch, run_segments, segment_plan
from repro.grid.spec import CellFailure, GridResult, GridSpec


def _pad_cap(arr: np.ndarray, cap: int) -> np.ndarray:
    """Zero-pad axis 1 (per-client capacity) of (N, cap_i, ...) to `cap`."""
    if arr.shape[1] == cap:
        return arr
    widths = [(0, 0), (0, cap - arr.shape[1])] + [(0, 0)] * (arr.ndim - 2)
    return np.pad(arr, widths)


def _build_batch(part: Partition, cfgs, setups, sel_specs,
                 rounds: int) -> ReplicaBatch:
    """Stack one partition's cells along a leading replica axis.  Replicas
    may have different per-client capacities (each seed re-partitions its
    data); stacks pad to the partition max — padding is never read because
    minibatch indices are sampled below each client's n_valid."""
    from repro.engine.scan_engine import build_epochs_table, build_fault_table

    idxs = part.cell_indices
    sub = [setups[i] for i in idxs]
    cap = max(int(s.xs.shape[1]) for s in sub)
    stack = np.stack
    return ReplicaBatch(
        carry=SegmentCarry(
            params=tree_stack([s.params for s in sub]),
            sel_state=tree_stack([s.sel_state for s in sub]),
            key=jnp.stack([s.key for s in sub]),
            eval_slot=jnp.zeros((len(sub),), jnp.int32)),
        xs=jnp.asarray(stack([_pad_cap(np.asarray(s.xs), cap)
                              for s in sub])),
        ys=jnp.asarray(stack([_pad_cap(np.asarray(s.ys), cap)
                              for s in sub])),
        nv=jnp.asarray(stack([np.asarray(s.n_valid) for s in sub])),
        sigma=jnp.asarray(stack([s.sigma_k_all for s in sub])),
        x_val=jnp.asarray(stack([np.asarray(s.x_val) for s in sub])),
        y_val=jnp.asarray(stack([np.asarray(s.y_val) for s in sub])),
        x_test=jnp.asarray(stack([np.asarray(s.x_test) for s in sub])),
        y_test=jnp.asarray(stack([np.asarray(s.y_test) for s in sub])),
        fractions=jnp.asarray(stack([np.asarray(s.fractions, np.float32)
                                     for s in sub])),
        epochs_tables=jnp.asarray(stack([
            build_epochs_table(cfgs[i], setups[i]) for i in idxs])),
        fault_tables=jnp.asarray(stack([
            build_fault_table(cfgs[i], setups[i]) for i in idxs])),
        d_scheds=jnp.asarray(stack([
            poc_d_schedule(sel_specs[i], rounds) for i in idxs])),
        eval_masks=jnp.asarray(stack([
            eval_mask(rounds, cfgs[i].eval_every) for i in idxs])),
        strategy_ids=jnp.asarray(part.strategy_ids, jnp.int32),
    )


# Revision of the segment-snapshot layout (the SegmentCarry pytree plus
# the stacked segment outputs saved next to it): bump whenever either
# structure changes so stale checkpoint dirs fail with an actionable
# version-skew error instead of an opaque structure mismatch from
# load_pytree.  1 = PR-3 (params, sel_state, key); 2 = + eval_slot
# (DESIGN.md §13); 3 = + per-round `granted` cohort sizes in the segment
# outputs (DESIGN.md §18); 4 = + per-round `quarantined` counts in the
# segment outputs (DESIGN.md §19).
CARRY_FORMAT = 4

# Revision of the cell -> partition assignment rule.  Folded into the
# checkpoint fingerprint because segment snapshots are tagged by
# partition index ("p0-seg0000.npz"): a partitioning change re-numbers
# the tags, so resuming across it would restore the wrong cells' state.
# 1 = capability pair; 2 = capability pair x upload_codec (§18).
PARTITION_REV = 2


def _check_fingerprint(checkpoint_dir: str, spec: GridSpec,
                       rounds_per_segment: int, resume: bool) -> None:
    """Refuse to resume another grid's checkpoints: segment snapshots are
    only distinguished by tree structure/shapes, so a config change that
    keeps shapes (seeds, knobs, a same-capability selector swap) would
    otherwise silently restore the previous experiment's results."""
    import hashlib
    import json
    import os

    fp = hashlib.sha256(repr(
        (spec.base, spec.cells, rounds_per_segment,
         PARTITION_REV)).encode()).hexdigest()
    path = os.path.join(checkpoint_dir, "grid.json")
    if os.path.exists(path):
        with open(path) as f:
            saved = json.load(f)
        if resume and saved.get("carry_format", 1) != CARRY_FORMAT:
            raise ValueError(
                f"checkpoint_dir {checkpoint_dir!r} holds segments in "
                f"carry format {saved.get('carry_format', 1)} but this "
                f"version writes format {CARRY_FORMAT} (the SegmentCarry "
                "layout changed); the snapshots cannot be resumed — "
                "point the run at a fresh directory")
        if resume and saved.get("fingerprint") != fp:
            raise ValueError(
                f"checkpoint_dir {checkpoint_dir!r} holds segments of a "
                "DIFFERENT grid (config fingerprint mismatch); point the "
                "run at a fresh directory or pass resume=False to "
                "overwrite")
    os.makedirs(checkpoint_dir, exist_ok=True)
    with open(path, "w") as f:
        json.dump({"fingerprint": fp, "carry_format": CARRY_FORMAT}, f)


def run_grid(spec: GridSpec, *, data=None, model=None,
             rounds_per_segment: int = 0,
             checkpoint_dir: Optional[str] = None, resume: bool = True,
             shard: bool = True, max_segments: Optional[int] = None,
             compile_stats: bool = False, telemetry=None,
             isolate_cells: bool = True, retries: int = 0,
             retry_backoff_s: float = 0.05) -> Optional[GridResult]:
    """Execute a grid.  Returns None if `max_segments` stopped the run
    before completion (the checkpoints on disk are the resume point).

    Graceful degradation (§19): with `isolate_cells=True` (default) a
    partition whose dispatch raises no longer kills the sweep — its
    cells come back as `CellFailure` entries (error + traceback payload,
    one `cell_failed` telemetry event per cell) in `GridResult.results`
    while every other partition completes normally.  Spec validation,
    the segment plan, and checkpoint fingerprint checks still raise
    up-front: those are caller errors, not cell failures.  `retries` /
    `retry_backoff_s` pass through to `run_segments` for transient
    per-segment retry before a partition is declared failed.

    * `rounds_per_segment=K` chains T/K dispatches of one compiled
      K-round segment per partition instead of a single whole-run scan —
      bit-identical results, checkpointable at every boundary.
    * `checkpoint_dir` snapshots each segment (carry + outputs); with
      `resume=True` a rerun restores the checkpointed prefix and only
      dispatches what is missing.
    * `shard=True` places the replica axis on a 1-D device mesh
      (repro.grid.shard) whenever >1 local device divides the partition's
      replica count; with one device it is the plain vmap path.  With
      `spec.base.clients_shards > 1` the mesh gains a client axis and the
      per-client state additionally shards over it (DESIGN.md §16) —
      batches are zero-padded to a shard multiple and results unpadded
      back, bit-identical to the dense grid.
    * `data` may be one dataset (shared by every cell) or a sequence with
      one dataset per cell (e.g. per-seed datasets of a benchmark table).
    * `telemetry` (repro.telemetry.Telemetry, default None = zero-cost)
      emits the grid's structured event stream (DESIGN.md §15): run_start
      with provenance, per-segment events + heartbeat (run_segments),
      per-cell `round_metrics`/`eval` unrolled at partition boundaries,
      checkpoint events, run_end.  Deliberately NOT part of GridSpec, so
      the checkpoint fingerprint — and resumability — are unaffected.
    """
    from repro.engine.scan_engine import make_scan_spec, results_from_scan
    from repro.federated.server import setup_run
    from repro.grid.shard import (
        CLIENT_AXIS, make_run_mesh, pad_batch_clients, unpad_scan_output,
    )

    t_start = time.perf_counter()
    cfgs = spec.validate()
    segment_plan(spec.base.rounds, rounds_per_segment)  # fail fast
    if spec.base.clients_shards > 1 and not shard:
        raise ValueError("clients_shards > 1 requires shard=True (the "
                         "client axis lives on the run mesh)")
    # a per-cell sequence is a plain list/tuple; SynthDataset itself is a
    # NamedTuple (hence a tuple), so ``_fields`` distinguishes the two
    if isinstance(data, (list, tuple)) and not hasattr(data, "_fields"):
        if len(data) != len(cfgs):
            raise ValueError(f"got {len(data)} datasets for "
                             f"{len(cfgs)} grid cells")
        cell_data = list(data)
    else:
        cell_data = [data] * len(cfgs)
    setups = [setup_run(c, d, model) for c, d in zip(cfgs, cell_data)]
    model = setups[0].model
    sel_specs = [s.sel_spec for s in setups]
    # the codec joins the partition key: it is jit-static inside the round
    # body, so each codec group gets its own executable (DESIGN.md §18)
    partitions = partition_cells(sel_specs,
                                 [c.upload_codec for c in cfgs])

    if checkpoint_dir:
        _check_fingerprint(checkpoint_dir, spec, rounds_per_segment,
                           resume)

    if telemetry is not None:
        from repro.telemetry.events import provenance
        from repro.telemetry.metrics import run_end_payload
        telemetry.emit(
            "run_start", run_id=telemetry.run_id, kind="grid",
            cells=len(cfgs), partitions=len(partitions),
            rounds=spec.base.rounds, rounds_per_segment=rounds_per_segment,
            checkpoint_dir=checkpoint_dir, provenance=provenance())

    from repro.telemetry.profile import trace_capture

    per_partition: list = []
    reports: list = []
    n_segments = 1
    compile_s = 0.0
    peaks: list = []   # per-partition compiled peak bytes (compile_stats)
    cards: list = []   # per-partition step cost cards (telemetry.profile)
    with trace_capture(telemetry, label="grid"):
        for pi, part in enumerate(partitions):
            t_part = time.perf_counter()
            try:
                live = bool(telemetry is not None and telemetry.live_tap)
                mesh = (make_run_mesh(len(part.cell_indices),
                                      spec.base.clients_shards)
                        if shard else None)
                client_sharded = (mesh is not None
                                  and CLIENT_AXIS in mesh.axis_names)
                scan_spec = make_scan_spec(
                    cfgs[part.cell_indices[0]], part.specs, live_tap=live,
                    client_axis=CLIENT_AXIS if client_sharded
                    else None)._replace(
                        rounds_per_segment=rounds_per_segment)
                batch = _build_batch(part, cfgs, setups, sel_specs,
                                     spec.base.rounds)
                if client_sharded:
                    batch = pad_batch_clients(batch,
                                              spec.base.clients_shards)
                if telemetry is not None:
                    telemetry.heartbeat(
                        f"partition {pi + 1}/{len(partitions)} "
                        f"({part.key.label}, "
                        f"{len(part.cell_indices)} cells)", force=True)
                out, report = run_segments(
                    model, cfgs[part.cell_indices[0]].client, scan_spec,
                    batch, checkpoint_dir=checkpoint_dir, tag=f"p{pi}-",
                    resume=resume, max_segments=max_segments, mesh=mesh,
                    compile_stats=compile_stats, telemetry=telemetry,
                    retries=retries, retry_backoff_s=retry_backoff_s)
                compile_s += report.compile_time_s
                peaks.append(report.peak_bytes)
                cards.append(report.cost_card)
                if out is None:
                    if telemetry is not None:
                        telemetry.heartbeat(
                            f"partition {pi + 1}: stopped at max_segments="
                            f"{max_segments} ({report.dispatches} "
                            "dispatched); checkpoints are the resume "
                            "point", force=True)
                    return None
                if client_sharded:
                    out = unpad_scan_output(out, spec.base.n_clients)
                n_segments = report.n_segments
                # the partition's cells ran fused: they share ITS duration
                # (not the grid's running total, which would bill later
                # partitions for earlier ones' work)
                wall = time.perf_counter() - t_part
                results = []
                evals_total = 0
                for j, idx in enumerate(part.cell_indices):
                    out_j = jax.tree.map(lambda x: x[j], out)
                    res = results_from_scan(
                        cfgs[idx], setups[idx], out_j, wall_time_s=wall,
                        seed=cfgs[idx].seed, dispatches=report.n_segments,
                        uses_shapley=part.key.needs_sv,
                        compile_time_s=report.compile_time_s)
                    evals_total += res.shapley_evals
                    results.append(res)
                    if telemetry is not None:
                        from repro.engine.schedule import eval_mask as _emask
                        from repro.federated.compression import codec_nbytes
                        from repro.telemetry.metrics import emit_scan_rounds
                        emit_scan_rounds(
                            telemetry, out_j,
                            uses_shapley=part.key.needs_sv,
                            codec_bytes=codec_nbytes(
                                cfgs[idx].upload_codec, setups[idx].params),
                            model_bytes=setups[idx].model_bytes,
                            emask=_emask(spec.base.rounds,
                                         cfgs[idx].eval_every),
                            cell=idx)
                per_partition.append(results)
                reports.append(PartitionReport(
                    label=part.key.label, cell_indices=part.cell_indices,
                    needs_sv=part.key.needs_sv,
                    uses_local_losses=part.key.uses_local_losses,
                    n_strategies=len(part.specs),
                    dispatches=report.dispatches,
                    shapley_evals=evals_total,
                    bytes_resident=report.bytes_resident,
                    flops_per_dispatch=report.flops_per_dispatch,
                    peak_bytes=report.peak_bytes,
                    upload_codec=part.key.upload_codec))
            except Exception as e:
                # cell isolation (§19): a raising partition degrades to
                # per-cell CellFailure entries instead of killing the
                # sweep.  KeyboardInterrupt (BaseException) still aborts.
                if not isolate_cells:
                    raise
                import traceback as _tb
                tb = _tb.format_exc()
                failures = []
                for idx in part.cell_indices:
                    if telemetry is not None:
                        telemetry.emit(
                            "cell_failed", cell=idx, error=repr(e),
                            selector=cfgs[idx].selector,
                            seed=cfgs[idx].seed, partition=part.key.label)
                    failures.append(CellFailure(
                        cell=idx, selector=cfgs[idx].selector,
                        seed=cfgs[idx].seed, partition=part.key.label,
                        error=repr(e), traceback=tb))
                per_partition.append(failures)
                reports.append(PartitionReport(
                    label=part.key.label, cell_indices=part.cell_indices,
                    needs_sv=part.key.needs_sv,
                    uses_local_losses=part.key.uses_local_losses,
                    n_strategies=len(part.specs), dispatches=0,
                    shapley_evals=0, bytes_resident=0,
                    upload_codec=part.key.upload_codec))
                if telemetry is not None:
                    telemetry.heartbeat(
                        f"partition {pi + 1}/{len(partitions)} FAILED "
                        f"({part.key.label}): {e!r} — "
                        f"{len(part.cell_indices)} cells degraded",
                        force=True)

    results = interleave(len(spec.cells), partitions, per_partition)
    wall = time.perf_counter() - t_start
    if telemetry is not None:
        accs = [r.final_acc for r in results if r.final_acc == r.final_acc]
        mem_fields = {}
        if any(p is not None for p in peaks):
            # compiled peak (per device) of the largest partition's step
            mem_fields["peak_bytes"] = max(
                p for p in peaks if p is not None)
        live_cards = [c for c in cards if c is not None]
        if live_cards:
            # the grid-level cost card is the heaviest partition's — the
            # executable whose peak bounds the run's memory footprint
            mem_fields["cost_card"] = max(
                live_cards, key=lambda c: c.get("peak_bytes") or 0)
        telemetry.emit("compile", seconds=compile_s,
                       program="grid_segments", **mem_fields)
        telemetry.emit("run_end", **run_end_payload(
            rounds=spec.base.rounds, wall_time_s=wall,
            compile_time_s=compile_s,
            final_acc=sum(accs) / len(accs) if accs else float("nan"),
            utility_evals=sum(r.shapley_evals for r in results),
            upload_bytes=sum(r.upload_bytes for r in results),
            download_bytes=sum(r.download_bytes for r in results),
            dispatches=sum(rep.dispatches for rep in reports)))
    return GridResult(
        spec=spec,
        results=results,
        partitions=reports,
        rounds_per_segment=rounds_per_segment,
        n_segments=n_segments,
        wall_time_s=wall)

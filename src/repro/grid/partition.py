"""Partitioned dispatch: group grid cells by execution capability.

`run_replicated_scan`'s single mixed batch runs with superset semantics —
GTG-Shapley (and Power-of-Choice local losses) are traced and executed
for EVERY replica whenever ANY strategy needs them, so the FedAvg/random
cells of a benchmark table pay the full Shapley cost for values they
discard (ROADMAP "mixed-strategy superset cost").  Here cells are grouped
by the capability triple `(uses_shapley, uses_local_losses,
upload_codec)`: each group compiles its own executable whose RoundSpec
only contains what the group needs (the codec is jit-static inside the
round body, so a mixed-codec grid NEEDS one executable per codec), and
per-group results are re-interleaved into grid order.  That makes a
selection x compression Pareto sweep a single `run_grid` call with
at most `capability-classes x codecs` compiles (DESIGN.md §18).

Cost of the "sv" partition (compiled-flops evidence in BENCH_grid.json):
with the default streaming prefix-Shapley path (DESIGN.md §14) the SV
step adds O(R_perms * M * D) FLOPs per round — the prefix models are
running sums, not the dense O(R_perms * M^2 * D) contraction of the §8
oracle — and `FLConfig.sv_chunk` bounds its peak memory at
O(max(sv_chunk, M) * D) per replica, so partitioning decides *who pays
the SV step*, while the streaming path decides *how small that step is*.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Sequence

from repro.core.selection_jax import SelectorSpec


class PartitionKey(NamedTuple):
    needs_sv: bool
    uses_local_losses: bool
    upload_codec: str = "identity"

    @property
    def label(self) -> str:
        base = ("sv" if self.needs_sv
                else "losses" if self.uses_local_losses else "plain")
        # identity keeps the bare capability label (and the historical
        # checkpoint tags); compressed partitions append their codec
        if self.upload_codec == "identity":
            return base
        return f"{base}+{self.upload_codec}"


class Partition(NamedTuple):
    """One capability group of a grid, in replica-batch form."""
    key: PartitionKey
    cell_indices: tuple          # positions in the grid's flat cell order
    specs: tuple                 # deduped SelectorSpecs (lax.switch table)
    strategy_ids: tuple          # per replica: index into `specs`


class PartitionReport(NamedTuple):
    """Host-side execution evidence per partition (BENCH_grid.json)."""
    label: str
    cell_indices: tuple
    needs_sv: bool
    uses_local_losses: bool
    n_strategies: int
    dispatches: int              # segment dispatches issued (resume: fewer)
    shapley_evals: int           # total utility evals across the partition
    bytes_resident: int          # replica-stacked operand + carry bytes
    flops_per_dispatch: float = float("nan")   # compiled cost, if available
    # XLA memory_analysis() peak of the compiled segment step (per device
    # under sharding); None unless run_grid(compile_stats=True)
    peak_bytes: Optional[int] = None
    upload_codec: str = "identity"   # the partition's jit-static codec


def partition_key(spec: SelectorSpec,
                  upload_codec: str = "identity") -> PartitionKey:
    return PartitionKey(bool(spec.uses_shapley),
                        bool(spec.uses_local_losses),
                        str(upload_codec))


def partition_cells(specs: Sequence[SelectorSpec],
                    upload_codecs: Optional[Sequence[str]] = None) -> list:
    """Group cell selector-specs into Partitions (stable order: first
    appearance of each capability class; cells keep grid order within).

    `upload_codecs` gives each cell's jit-static codec (default: all
    identity, the pre-§18 behaviour); cells only share an executable —
    a partition — when BOTH the capability pair and the codec agree.

    Identical SelectorSpecs share one switch branch, so a partition of R
    seeds x one strategy dispatches statically (len(specs) == 1)."""
    if upload_codecs is None:
        upload_codecs = ["identity"] * len(specs)
    if len(upload_codecs) != len(specs):
        raise ValueError(f"got {len(upload_codecs)} upload_codecs for "
                         f"{len(specs)} cells")
    groups: dict = {}
    order: list = []
    for i, spec in enumerate(specs):
        k = partition_key(spec, upload_codecs[i])
        if k not in groups:
            groups[k] = []
            order.append(k)
        groups[k].append((i, spec))
    parts = []
    for k in order:
        uniq: list = []
        sids = []
        for _, spec in groups[k]:
            if spec not in uniq:
                uniq.append(spec)
            sids.append(uniq.index(spec))
        parts.append(Partition(
            key=k,
            cell_indices=tuple(i for i, _ in groups[k]),
            specs=tuple(uniq),
            strategy_ids=tuple(sids)))
    return parts


def interleave(n_cells: int, partitions: Sequence[Partition],
               per_partition: Sequence[list]) -> list:
    """Scatter per-partition result lists back into grid cell order."""
    out = [None] * n_cells
    for part, results in zip(partitions, per_partition):
        if len(part.cell_indices) != len(results):
            raise ValueError(
                f"partition {part.key.label!r} returned {len(results)} "
                f"results for {len(part.cell_indices)} cells")
        for idx, res in zip(part.cell_indices, results):
            out[idx] = res
    missing = [i for i, r in enumerate(out) if r is None]
    if missing:
        raise ValueError(f"grid cells {missing} were not covered by any "
                         "partition")
    return out

"""GridSpec/GridResult — the declarative experiment-grid API.

A grid is a tuple of `(strategy, seed, knob-overrides)` cells over one
base FLConfig.  Cells may vary anything that becomes a *per-replica
operand* of the scan program (seed, selector, selector kwargs, Dirichlet
alpha, straggler fraction, privacy sigma, timing schedule, and — since
the eval-mask table of DESIGN.md §13 — the eval cadence `eval_every`);
everything that is baked into the trace as a static — shapes, round
budget, client config, Shapley settings — must be uniform, and
`validate()` rejects mixed values with a precise error before anything
compiles.  `upload_codec` is jit-static too, but instead of being
rejected it joins the partition key (DESIGN.md §18): cells with
different codecs land in different partitions, each compiling its own
executable, so a selection x compression Pareto sweep is ONE run_grid
call.  `repro.grid.runner.run_grid` is the executor.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Mapping, Optional, Sequence

import numpy as np

# FLConfig fields that are compiled into the partition executable (shapes
# or jit-static spec fields): every cell of a grid must agree on them.
# `upload_codec` is deliberately absent — it is jit-static per executable
# but partition-varying: repro.grid.partition groups cells by codec and
# each codec group compiles its own executable.
STATIC_FIELDS = (
    "dataset", "n_clients", "m", "rounds", "client",
    "n_train", "n_val", "n_test",
    "shapley_eps", "shapley_max_iters", "shapley_impl", "sv_chunk",
    "clients_shards",
    "faults", "quarantine", "quarantine_z",
)

def _freeze_overrides(ov) -> tuple:
    if ov is None:
        return ()
    if isinstance(ov, Mapping):
        items = ov.items()
    else:
        items = tuple(ov)
    return tuple(sorted((str(k), v) for k, v in items))


@dataclasses.dataclass(frozen=True)
class GridCell:
    """One grid cell: a strategy at a seed, plus FLConfig knob overrides."""
    selector: str
    seed: int
    overrides: Any = ()          # mapping | items; frozen to sorted items

    def __post_init__(self):
        object.__setattr__(self, "overrides",
                           _freeze_overrides(self.overrides))

    def config(self, base):
        """The cell's concrete FLConfig (engine pinned to 'scan')."""
        kw = dict(self.overrides)
        kw.update(selector=self.selector, seed=self.seed, engine="scan")
        return dataclasses.replace(base, **kw)


@dataclasses.dataclass(frozen=True)
class GridSpec:
    """A declarative grid: base FLConfig + cells, validated before compile."""
    base: Any                    # FLConfig
    cells: tuple                 # tuple[GridCell, ...]

    def __post_init__(self):
        object.__setattr__(self, "cells", tuple(self.cells))
        if not self.cells:
            raise ValueError("GridSpec needs at least one cell")

    @staticmethod
    def product(base, selectors: Optional[Sequence[str]] = None,
                seeds: Sequence[int] = (0,),
                overrides=None) -> "GridSpec":
        """The benchmark-table grid: selectors x seeds (selector-major,
        seed-minor — the `run_replicated_scan` result order), with one
        shared overrides mapping applied to every cell."""
        names = list(selectors) if selectors else [base.selector]
        seeds = list(seeds)
        if not seeds:
            raise ValueError("GridSpec.product needs at least one seed")
        return GridSpec(base, tuple(
            GridCell(name, seed, overrides)
            for name in names for seed in seeds))

    def cell_configs(self) -> list:
        return [cell.config(self.base) for cell in self.cells]

    def validate(self) -> list:
        """Check grid-wide static uniformity; returns the cell FLConfigs."""
        from repro.federated.compression import CODECS

        cfgs = self.cell_configs()
        for i, cfg in enumerate(cfgs):
            for f in STATIC_FIELDS:
                if getattr(cfg, f) != getattr(self.base, f):
                    raise ValueError(
                        f"grid cells must agree on jit-static FLConfig "
                        f"field {f!r}: cell {i} has {getattr(cfg, f)!r}, "
                        f"base has {getattr(self.base, f)!r}")
            if cfg.upload_codec not in CODECS:
                raise ValueError(
                    f"cell {i} has unknown upload_codec "
                    f"{cfg.upload_codec!r}; known: {sorted(CODECS)}")
        return cfgs


@dataclasses.dataclass(frozen=True)
class CellFailure:
    """Degraded grid entry (§19): the cell's partition raised instead of
    producing an FLResult.  Carries the error payload for triage; the
    numeric class attributes keep naive aggregations (mean accuracy,
    byte totals) well-defined without special-casing — NaN accuracy
    drops out of mean/filters, zero bytes add nothing."""
    cell: int                    # index into GridSpec.cells
    selector: str
    seed: int
    partition: str               # PartitionKey.label of the failed dispatch
    error: str                   # repr() of the raised exception
    traceback: str
    final_acc: float = float("nan")
    shapley_evals: int = 0
    upload_bytes: int = 0
    download_bytes: int = 0


@dataclasses.dataclass
class GridResult:
    """Grid outputs in cell order, plus execution-shape bookkeeping."""
    spec: GridSpec
    results: list                # FLResult per cell, same order as cells
    partitions: list             # repro.grid.partition.PartitionReport
    rounds_per_segment: int
    n_segments: int
    wall_time_s: float

    def cell(self, selector: str, seed: int):
        """The FLResult of one (selector, seed) cell (first match)."""
        for c, r in zip(self.spec.cells, self.results):
            if c.selector == selector and c.seed == seed:
                return r
        raise KeyError(f"no grid cell ({selector!r}, seed={seed})")

    def select(self, selector: str) -> list:
        return [r for c, r in zip(self.spec.cells, self.results)
                if c.selector == selector]

    def acc_summary(self) -> dict:
        """selector -> (mean, std) of final accuracy across its SURVIVING
        cells (CellFailure entries are excluded; a selector whose cells
        all failed is absent from the summary)."""
        out: dict = {}
        for c, r in zip(self.spec.cells, self.results):
            if isinstance(r, CellFailure):
                continue
            out.setdefault(c.selector, []).append(r.final_acc)
        return {k: (float(np.mean(v)), float(np.std(v)))
                for k, v in out.items()}

    @property
    def failures(self) -> list:
        """The grid's CellFailure entries (empty on a clean run)."""
        return [r for r in self.results if isinstance(r, CellFailure)]

    @property
    def dispatches(self) -> int:
        return sum(p.dispatches for p in self.partitions)

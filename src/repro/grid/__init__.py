"""repro.grid — the sharded, segmented, resumable experiment-grid runner.

The paper's headline results are grids: every table sweeps strategies x
seeds x heterogeneity/timing/privacy knobs under a fixed round budget.
This package turns `run_replicated_scan`'s whole-run `lax.scan` from a
benchmark trick into the production execution path for such grids
(DESIGN.md §12):

  * `spec`      — GridSpec/GridCell/GridResult: the declarative grid API;
  * `partition` — replicas grouped by capability (needs_sv / local
                  losses) so FedAvg-family cells stop paying GTG-Shapley
                  superset cost;
  * `segments`  — the scan-of-scans: one compiled K-round segment chained
                  T/K times, carry checkpointed at every boundary for
                  bit-identical resume;
  * `shard`     — the replica axis placed on a mesh axis so grid memory
                  scales with replicas / n_devices;
  * `runner`    — `run_grid`, the single entry point.
"""
from repro.grid.runner import run_grid
from repro.grid.spec import CellFailure, GridCell, GridResult, GridSpec

__all__ = ["CellFailure", "GridCell", "GridResult", "GridSpec", "run_grid"]

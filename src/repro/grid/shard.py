"""Sharded replicas + sharded clients: the grid on a 2-D run mesh.

One scan program holds the full (R, N, cap, ...) client stacks plus the
(R, T, M) outputs resident; replica batches multiply the PR-2 footprint,
so "millions of users" grids need memory that scales with
replicas / n_devices (ROADMAP "scan memory at paper scale").  Two mesh
axes split that footprint (DESIGN.md §12, §16):

  * `REPLICA_AXIS` — replicas are embarrassingly parallel: every operand
    of the vmapped segment step carries a leading replica axis and
    replicas never communicate, so a sharding-annotated jit over the
    replica axis partitions everything with no collectives — the
    executable is the same segment program placed `n_devices` times.
    Only `t0` (the shared global round offset) and `eval_any_seg` (the
    OR of the replicas' eval-mask rows, DESIGN.md §13) stay replicated,
    which also keeps the in-scan eval cond a real branch.

  * `CLIENT_AXIS` — the population axis: the (R, N, cap, ...) data
    stacks, per-client schedule tables, and the per-client selector-state
    vectors additionally shard their N axis (padded to a multiple of the
    shard count by `pad_batch_clients`), making per-device client memory
    O(N / clients_shards).  Clients DO communicate — selection is a
    global top-m and the cohort is gathered across shards — so this path
    is an explicit `shard_map`: the selector state is all-gathered to its
    exact (N,) form per round and the cohort rows combine via the
    bitcast-psum gather in `repro.kernels.cohort_gather`.  Sharded and
    dense runs are bit-identical by construction (gathers copy bits; the
    strategies run on the same (N,) state either way), pinned by
    tests/test_client_sharding.py.

CI validates both paths on the forced-host 8-device debug mesh
(tests/test_grid.py, tests/test_client_sharding.py, subprocess — the
main pytest process must keep seeing one CPU device).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.selection_jax import DeviceSelectorState
from repro.core.valuation import ValuationState
from repro.engine.round_engine import (
    ScanSpec, SegmentCarry, SegmentOutput, make_segment_step,
)
from repro.launch.mesh import (  # re-export
    CLIENT_AXIS, REPLICA_AXIS, make_replica_mesh, make_run_mesh,
)

__all__ = ["CLIENT_AXIS", "REPLICA_AXIS", "make_replica_mesh",
           "make_run_mesh", "sharded_segment_step", "clients_padded",
           "pad_batch_clients", "unpad_scan_output"]


@functools.lru_cache(maxsize=8)
def _sharded_segment_step_cached(model, ccfg, spec: ScanSpec, mesh):
    fn = jax.vmap(make_segment_step(model, ccfg, spec),
                  in_axes=(0, None, None) + (0,) * 14)
    rep = NamedSharding(mesh, P(REPLICA_AXIS))   # leading-axis shard …
    full = NamedSharding(mesh, P())              # … t0 / eval_any replicated
    # pytree-prefix shardings: one leaf sharding covers a whole operand
    # subtree (carry pytree included)
    in_shardings = (rep, full, full) + (rep,) * 14
    return jax.jit(fn, in_shardings=in_shardings, out_shardings=rep)


def _carry_specs():
    """PartitionSpec pytree of a replica-stacked SegmentCarry on the 2-D
    mesh: params/key/eval_slot shard only over replicas; the per-client
    selector-state vectors ((R, N_pad) leaves) also shard over clients;
    scalar selector fields ((R,) round/frozen) stay client-replicated."""
    rep = P(REPLICA_AXIS)
    rc = P(REPLICA_AXIS, CLIENT_AXIS)
    return SegmentCarry(
        params=rep,
        sel_state=DeviceSelectorState(
            valuation=ValuationState(sv=rc, counts=rc, initialised=rc),
            round=rep, rr_order=rc, active=rc, frozen=rep),
        key=rep, eval_slot=rep)


@functools.lru_cache(maxsize=8)
def _client_sharded_step_cached(model, ccfg, spec: ScanSpec, mesh):
    # the scan body only emits the cross-shard collectives when the spec
    # names the client axis — a mismatch would deadlock or miscompute
    assert spec.round.client_axis == CLIENT_AXIS, spec.round.client_axis
    fn = jax.vmap(make_segment_step(model, ccfg, spec),
                  in_axes=(0, None, None) + (0,) * 14)
    rep = P(REPLICA_AXIS)
    rc = P(REPLICA_AXIS, CLIENT_AXIS)
    carry = _carry_specs()
    # operands after carry: t0, eval_any_seg, xs, ys, nv, sigma, x_val,
    # y_val, x_test, y_test, fractions, epochs_tables, fault_tables,
    # d_scheds, eval_masks, strategy_ids.  fractions stays replicated
    # (exact (N,) vector, read whole by selection); the epochs and fault
    # tables shard their trailing client axis.
    in_specs = (carry, P(), P(), rc, rc, rc, rc, rep, rep, rep, rep, rep,
                P(REPLICA_AXIS, None, CLIENT_AXIS),
                P(REPLICA_AXIS, None, CLIENT_AXIS), rep, rep, rep)
    out_specs = SegmentOutput(carry=carry, selections=rep, epochs=rep,
                              sv=rep, utility_evals=rep, sv_truncated=rep,
                              test_acc=rep, val_loss=rep, granted=rep,
                              quarantined=rep)
    # check_rep=False: the round outputs ARE replicated over clients (the
    # psum-combined cohort is identical on every shard) but shard_map's
    # replication checker cannot prove it through the scan
    sm = shard_map(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                   check_rep=False)
    return jax.jit(sm)


def sharded_segment_step(model, ccfg, spec: ScanSpec, mesh):
    """Compiled segment step for `mesh`: replica-sharded jit on a 1-D
    replica mesh, explicit shard_map when the mesh has a client axis of
    size > 1; cached like `jitted_segment_step` so all segments (and
    repeat runs) share one executable."""
    if CLIENT_AXIS in mesh.axis_names and mesh.shape[CLIENT_AXIS] > 1:
        return _client_sharded_step_cached(model, ccfg, spec, mesh)
    if mesh.shape[REPLICA_AXIS] <= 1:
        from repro.engine.round_engine import jitted_segment_step
        return jitted_segment_step(model, ccfg, spec, vmapped=True)
    return _sharded_segment_step_cached(model, ccfg, spec, mesh)


# --------------------------------------------------------------------------
# client-axis padding: N must divide the shard count, so batches are padded
# to N_pad = ceil(N / shards) * shards; pad rows are zeros that no path ever
# reads (selection slices the gathered state to exact N, gathers only touch
# real ids, and `put_back` keeps pad rows at their initial values)
# --------------------------------------------------------------------------

def clients_padded(n_clients: int, shards: int) -> int:
    """Smallest multiple of `shards` >= n_clients."""
    return -(-n_clients // shards) * shards


def _pad_axis(x, axis: int, target: int):
    pad = target - x.shape[axis]
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def pad_batch_clients(batch, shards: int):
    """Zero-pad every client-axis array of a ReplicaBatch to a multiple of
    `shards`: data stacks (xs/ys/nv/sigma, axis 1), the epochs and fault
    tables (axis 2), and the (R, N) selector-state vectors.  Fractions
    and params are untouched (replicated, exact-N)."""
    n = batch.xs.shape[1]
    n_pad = clients_padded(n, shards)
    if n_pad == n:
        return batch
    sel_state = jax.tree.map(
        lambda x: _pad_axis(x, 1, n_pad) if x.ndim >= 2 else x,
        batch.carry.sel_state)
    return batch._replace(
        carry=batch.carry._replace(sel_state=sel_state),
        xs=_pad_axis(batch.xs, 1, n_pad),
        ys=_pad_axis(batch.ys, 1, n_pad),
        nv=_pad_axis(batch.nv, 1, n_pad),
        sigma=_pad_axis(batch.sigma, 1, n_pad),
        epochs_tables=_pad_axis(batch.epochs_tables, 2, n_pad),
        fault_tables=_pad_axis(batch.fault_tables, 2, n_pad))


def unpad_scan_output(out, n_clients: int):
    """Drop the pad rows from a ScanRunOutput's final selector state so
    downstream consumers (`results_from_scan`) see the exact (R, N)
    vectors a dense run would produce."""
    sel_state = jax.tree.map(
        lambda x: x[:, :n_clients] if x.ndim >= 2 else x,
        out.sel_state)
    return out._replace(sel_state=sel_state)

"""Sharded replicas: the grid's replica axis placed on a mesh axis.

One scan program holds the full (R, N, cap, ...) client stacks plus the
(R, T, M) outputs resident; replica batches multiply the PR-2 footprint,
so "millions of users" grids need memory that scales with
replicas / n_devices (ROADMAP "scan memory at paper scale").  Replicas
are embarrassingly parallel — every operand of the vmapped segment step
carries a leading replica axis and replicas never communicate — so a
sharding-annotated jit over a 1-D replica mesh partitions everything:
each device holds R / n_devices whole replicas, XLA inserts no
collectives, and the executable is the same segment program placed
`n_devices` times.  Only `t0` (the shared global round offset) and
`eval_any_seg` (the OR of the replicas' eval-mask rows, DESIGN.md §13)
stay replicated, which also keeps the in-scan eval cond a real branch.

CI validates the path on the forced-host 8-device debug mesh
(tests/test_grid.py, subprocess — the main pytest process must keep
seeing one CPU device).
"""
from __future__ import annotations

import functools

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.engine.round_engine import ScanSpec, make_segment_step
from repro.launch.mesh import REPLICA_AXIS, make_replica_mesh  # re-export

__all__ = ["REPLICA_AXIS", "make_replica_mesh", "sharded_segment_step"]


@functools.lru_cache(maxsize=8)
def _sharded_segment_step_cached(model, ccfg, spec: ScanSpec, mesh):
    fn = jax.vmap(make_segment_step(model, ccfg, spec),
                  in_axes=(0, None, None) + (0,) * 13)
    rep = NamedSharding(mesh, P(REPLICA_AXIS))   # leading-axis shard …
    full = NamedSharding(mesh, P())              # … t0 / eval_any replicated
    # pytree-prefix shardings: one leaf sharding covers a whole operand
    # subtree (carry pytree included)
    in_shardings = (rep, full, full) + (rep,) * 13
    return jax.jit(fn, in_shardings=in_shardings, out_shardings=rep)


def sharded_segment_step(model, ccfg, spec: ScanSpec, mesh):
    """Compiled segment step with every replica-stacked operand sharded
    over `mesh`'s replica axis; cached like `jitted_segment_step` so all
    segments (and repeat runs) share one executable."""
    if mesh.shape[REPLICA_AXIS] <= 1:
        from repro.engine.round_engine import jitted_segment_step
        return jitted_segment_step(model, ccfg, spec, vmapped=True)
    return _sharded_segment_step_cached(model, ccfg, spec, mesh)

"""Segmented execution: one compiled K-round segment, chained T/K times.

The whole-run scan returns only final state — a killed 400-round grid
restarts from zero (ROADMAP "checkpoint/restart of scan runs").  The
segment step (`round_engine.make_segment_step`) scans the SAME per-round
body for K = `rounds_per_segment` rounds and surfaces the carry (params,
selector state, rng key) to the host between dispatches, so:

  * execution stays O(1) dispatch per segment (T/K dispatches per run,
    ONE compiled executable reused across segments and across runs);
  * `checkpoint/ckpt.py` snapshots the carry — and the segment's stacked
    outputs — at every boundary;
  * a killed run resumes from the last complete segment bit-identically:
    the carry is the exact scan state, so selections, params, and the key
    stream continue as if never interrupted.

Chaining is bit-identical to the unsegmented scan because both scan the
same body over the same (t, epochs_row, d) sequence — segmentation only
changes where the host observes the carry.
"""
from __future__ import annotations

import glob
import os
import re
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.ckpt import (
    CheckpointCorruptError, load_carry, save_carry,
)
from repro.engine.round_engine import (
    ScanRunOutput, ScanSpec, SegmentCarry, jitted_segment_step,
)

PyTree = Any


class ReplicaBatch(NamedTuple):
    """A partition's replica-stacked scan operands (leading axis R)."""
    carry: SegmentCarry          # stacked params / selector state / keys
    xs: jax.Array                # (R, N, cap, ...)
    ys: jax.Array
    nv: jax.Array
    sigma: jax.Array
    x_val: jax.Array
    y_val: jax.Array
    x_test: jax.Array
    y_test: jax.Array
    fractions: jax.Array
    epochs_tables: jax.Array     # (R, T, N) int32
    fault_tables: jax.Array      # (R, T, N) int32 fault codes (§19)
    d_scheds: jax.Array          # (R, T) int32
    eval_masks: jax.Array        # (R, T) bool per-replica eval cadences
    strategy_ids: jax.Array      # (R,) int32 index into the partition specs


class SegmentRunReport(NamedTuple):
    n_segments: int
    dispatches: int              # segments dispatched by THIS call
    resumed_segments: int        # segments restored from checkpoints
    bytes_resident: int
    flops_per_dispatch: float
    compile_time_s: float = 0.0  # jit trace+lower+compile in THIS call
    # XLA memory_analysis() peak of the compiled segment step (per device
    # under sharding); None unless compile_stats/telemetry asked for the
    # probe or the backend has no analysis
    peak_bytes: Optional[int] = None
    # the full per-executable cost card (telemetry.profile) of the
    # segment step — flops, bytes accessed, memory classes, roofline;
    # populated under the same gate as peak_bytes
    cost_card: Optional[dict] = None


def segment_plan(rounds: int, rounds_per_segment: int) -> tuple[int, int]:
    """(K, n_segments); K=0 means unsegmented.  K must divide T so every
    segment reuses the one compiled executable."""
    k = rounds_per_segment or rounds
    if k <= 0 or rounds % k != 0:
        raise ValueError(
            f"rounds_per_segment={rounds_per_segment} must divide "
            f"rounds={rounds} (one executable serves every segment)")
    return k, rounds // k


def batch_bytes(batch: ReplicaBatch) -> int:
    """Device-resident bytes of the stacked operands + carry."""
    return sum(int(np.prod(x.shape)) * x.dtype.itemsize
               for x in jax.tree.leaves(batch)
               if hasattr(x, "shape") and hasattr(x, "dtype"))


def _out_like(spec: ScanSpec, n_replicas: int, k_rounds: int) -> dict:
    m = spec.selectors[0].m
    r, k = n_replicas, k_rounds
    return {
        "selections": np.zeros((r, k, m), np.int32),
        "epochs": np.zeros((r, k, m), np.int32),
        "sv": np.zeros((r, k, m), np.float32),
        "utility_evals": np.zeros((r, k), np.int32),
        "sv_truncated": np.zeros((r, k), bool),
        "test_acc": np.zeros((r, k), np.float32),
        "val_loss": np.zeros((r, k), np.float32),
        "granted": np.zeros((r, k), np.int32),
        "quarantined": np.zeros((r, k), np.int32),
    }


def _seg_path(checkpoint_dir: str, tag: str, seg: int) -> str:
    return os.path.join(checkpoint_dir, f"{tag}seg{seg:04d}.npz")


def saved_segments(checkpoint_dir: str, tag: str) -> int:
    """Length of the contiguous checkpointed-segment prefix on disk."""
    pat = re.compile(re.escape(tag) + r"seg(\d{4})\.npz$")
    have = set()
    for p in glob.glob(os.path.join(checkpoint_dir, f"{tag}seg*.npz")):
        mt = pat.search(os.path.basename(p))
        if mt:
            have.add(int(mt.group(1)))
    n = 0
    while n in have:
        n += 1
    return n


def _to_out_dict(out) -> dict:
    return {
        "selections": out.selections, "epochs": out.epochs, "sv": out.sv,
        "utility_evals": out.utility_evals,
        "sv_truncated": out.sv_truncated,
        "test_acc": out.test_acc, "val_loss": out.val_loss,
        "granted": out.granted, "quarantined": out.quarantined,
    }


def run_segments(model, ccfg, spec: ScanSpec, batch: ReplicaBatch, *,
                 checkpoint_dir: Optional[str] = None, tag: str = "",
                 resume: bool = True, max_segments: Optional[int] = None,
                 mesh=None, compile_stats: bool = False, telemetry=None,
                 retries: int = 0, retry_backoff_s: float = 0.05
                 ) -> tuple[Optional[ScanRunOutput], SegmentRunReport]:
    """Drive one partition's replica batch through all T/K segments.

    Returns (ScanRunOutput, report); the output is None when
    `max_segments` stopped the run early (the checkpoint prefix on disk
    is then the resume point — used by the kill/restart tests and by any
    externally killed run).

    Hardened resume (§19): a checkpoint that fails integrity checks
    (truncated write, digest mismatch) is treated as absent — the run
    falls back to the last intact segment boundary, emits a
    `checkpoint_corrupt` event, and recomputes forward (overwriting the
    bad file at the next boundary).  `retries` > 0 additionally retries
    a raising segment dispatch up to that many times with exponential
    backoff (`retry_backoff_s` doubling per attempt), emitting a
    `segment_retry` event per attempt — transient executor failures
    (preempted device, flaky interconnect) stop killing 400-round runs.

    `telemetry` (default None: zero extra dispatches, async dispatch
    chain untouched) emits `segment_start`/`segment_end` events with the
    aggregate gauges of `metrics.segment_counters`, checkpoint events,
    and a throttled per-segment heartbeat with an ETA from the mean
    dispatched-segment time plus the compiled per-device peak bytes, so
    a long grid surfaces memory pressure without opening the JSONL.
    Per-segment timing blocks on the segment's outputs — observed
    segments are timed honestly instead of billing a segment for its
    predecessors' async queue.  With a sink attached the first
    dispatched segment also emits a `compile` event carrying the step's
    cost card (telemetry.profile — an AOT probe, cached per executable,
    zero extra dispatches).
    """
    import time

    from repro.telemetry.metrics import segment_counters
    from repro.telemetry.profile import cached_cost_card
    from repro.telemetry.trace import CompileTimer, live_sink, stage

    k_rounds, n_segments = segment_plan(spec.rounds,
                                        spec.rounds_per_segment)
    n_replicas = int(batch.strategy_ids.shape[0])
    seg_spec = spec._replace(rounds_per_segment=k_rounds)
    ctimer = CompileTimer()
    live = bool(telemetry is not None and telemetry.live_tap)

    with ctimer:
        if mesh is not None:
            from repro.grid.shard import sharded_segment_step
            step = sharded_segment_step(model, ccfg, seg_spec, mesh)
        else:
            step = jitted_segment_step(model, ccfg, seg_spec, vmapped=True)

    carry = batch.carry
    operands = (batch.xs, batch.ys, batch.nv, batch.sigma, batch.x_val,
                batch.y_val, batch.x_test, batch.y_test, batch.fractions)
    # the in-scan eval cond fires where ANY replica's mask is set; the OR
    # row stays unbatched under the vmap so the cond remains a real branch
    eval_any = jnp.asarray(np.asarray(batch.eval_masks).any(axis=0))

    # ---- resume: restore the contiguous checkpointed prefix --------------
    outs: list[dict] = []
    start = 0
    out_like = _out_like(seg_spec, n_replicas, k_rounds)
    if checkpoint_dir and resume:
        limit = min(saved_segments(checkpoint_dir, tag), n_segments)
        start = limit
        for seg in range(limit):
            path = _seg_path(checkpoint_dir, tag, seg)
            try:
                snap = load_carry(path, {"carry": carry, "out": out_like},
                                  telemetry=telemetry)
            except CheckpointCorruptError as e:
                # fall back to the last intact boundary; the rounds from
                # here on are recomputed (bit-identical — same carry,
                # same tables) and the bad file overwritten on the way
                if telemetry is not None:
                    telemetry.emit("checkpoint_corrupt", path=path,
                                   segment=seg, tag=tag, error=str(e))
                start = seg
                break
            outs.append(snap["out"])
            carry = snap["carry"]

    flops = float("nan")
    peak_bytes = None
    card = None
    dispatched = 0
    seg_seconds: list[float] = []
    for seg in range(start, n_segments):
        if max_segments is not None and dispatched >= max_segments:
            return None, SegmentRunReport(
                n_segments, dispatched, start, batch_bytes(batch), flops,
                ctimer.seconds, peak_bytes, card)
        t0 = jnp.asarray(seg * k_rounds, jnp.int32)
        sl = slice(seg * k_rounds, (seg + 1) * k_rounds)
        args = (carry, t0, eval_any[sl], *operands,
                batch.epochs_tables[:, sl], batch.fault_tables[:, sl],
                batch.d_scheds[:, sl], batch.eval_masks[:, sl],
                batch.strategy_ids)
        if telemetry is not None:
            t_seg = time.perf_counter()
            telemetry.emit("segment_start", segment=seg,
                           t0=seg * k_rounds, rounds=k_rounds, tag=tag,
                           replicas=n_replicas)
        attempt = 0
        while True:
            try:
                with ctimer, live_sink(telemetry if live else None), \
                        stage("segment"):
                    out = step(*args)
                    if telemetry is not None or attempt > 0:
                        # taps must land (and the segment be timed) before
                        # the next dispatch is enqueued; under retry, force
                        # async dispatch errors to surface HERE
                        jax.block_until_ready(out.carry.params)
                break
            except Exception:
                # KeyboardInterrupt is BaseException — never swallowed
                if attempt >= retries:
                    raise
                attempt += 1
                if telemetry is not None:
                    telemetry.emit("segment_retry", segment=seg,
                                   attempt=attempt, tag=tag)
                time.sleep(retry_backoff_s * (2 ** (attempt - 1)))
        if (compile_stats or telemetry is not None) and seg == start:
            # the step's cost card (one cached AOT probe, §17): flops,
            # bytes, per-device peak memory, roofline terms
            card = cached_cost_card(step, *args)
            if card is not None:
                flops = card.get("flops", float("nan"))
                peak_bytes = card.get("peak_bytes")
            if telemetry is not None:
                telemetry.emit("compile", seconds=ctimer.seconds,
                               program=f"segment_step:{tag or 'solo'}",
                               cost_card=card)
        carry = out.carry
        dispatched += 1
        if telemetry is not None:
            secs = time.perf_counter() - t_seg
            seg_seconds.append(secs)
            telemetry.emit("segment_end", segment=seg, tag=tag,
                           **segment_counters(out, secs))
            mean_s = sum(seg_seconds) / len(seg_seconds)
            eta_s = mean_s * (n_segments - seg - 1)
            peak_txt = ("" if peak_bytes is None
                        else f" peak {peak_bytes / 1e6:.0f}MB/dev")
            telemetry.heartbeat(
                f"{tag or 'seg'} {seg + 1}/{n_segments} "
                f"({k_rounds} rounds x {n_replicas} replicas, "
                f"{secs:.2f}s) eta {eta_s:.0f}s{peak_txt}")
        if checkpoint_dir:
            save_carry(_seg_path(checkpoint_dir, tag, seg),
                       {"carry": out.carry, "out": _to_out_dict(out)},
                       telemetry=telemetry)
        outs.append(_to_out_dict(out))

    stacked = {k: jnp.concatenate([o[k] for o in outs], axis=1)
               for k in outs[0]}
    result = ScanRunOutput(
        params=carry.params, sel_state=carry.sel_state,
        selections=stacked["selections"], epochs=stacked["epochs"],
        sv=stacked["sv"], utility_evals=stacked["utility_evals"],
        sv_truncated=stacked["sv_truncated"],
        test_acc=stacked["test_acc"], val_loss=stacked["val_loss"],
        granted=stacked["granted"], quarantined=stacked["quarantined"],
        eval_count=carry.eval_slot)
    report = SegmentRunReport(n_segments, dispatched, start,
                              batch_bytes(batch), flops, ctimer.seconds,
                              peak_bytes, card)
    return result, report

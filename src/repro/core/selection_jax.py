"""Device-resident client selection — THE runtime selector stack.

Every engine (`loop`, `batched`, `scan`, the replica vmaps, and the grid
runner) selects through this module; the host classes in
`repro.core.selection` survive only as the tests' parity oracle
(DESIGN.md §13).  The six strategies are fixed-shape, jittable pure
functions:

    spec  = make_selector_spec("greedyfed", n_clients=N, m=M)
    state = init_device_state(spec, seed)
    sel, state = device_select(spec, state, key, ctx)   # traceable
    state      = device_update(spec, state, sel, sv)    # traceable

`SelectorSpec` is a hashable NamedTuple of python scalars — static under
`jit` — and `DeviceSelectorState` is a pytree of fixed-shape arrays (the
round-robin order, selection counts, EMA'd Shapley values, and the dropout
active-mask), so the state threads through `lax.scan` carries and vmaps
over a seed axis.  All strategies share one state/ctx signature, which
makes them `lax.switch`-dispatchable (`device_select_any`): a single
compiled program can serve a multi-strategy replica batch with a traced
per-replica `strategy_id`.

Parity contract (pinned by tests/test_selection.py): the host selectors
compute their scores/probabilities with the *shared jnp helpers below*
(`poc_probs`, `sfedavg_probs`, `ucb_scores`) and stable argsorts, so host
and device paths produce bit-identical selections from the same key.  Two
implementation notes that make that possible:

  * `jax.random.choice(key, n, (d,), replace=False[, p])` draws `n`
    gumbels (or a full permutation) and keeps the first `d` — the draw is
    a *prefix* of a fixed-shape order, so the decaying Power-of-Choice
    candidate count `d` becomes a traced mask over a static-shape sort
    instead of a dynamic shape.
  * `jnp.argsort` is stable, matching `np.argsort(kind="stable")`; ties
    resolve by client index on both paths.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.valuation import ValuationState, init_valuation, update_valuation


# --------------------------------------------------------------------------
# static config + state pytree
# --------------------------------------------------------------------------

class SelectorSpec(NamedTuple):
    """Hashable, jit-static description of one selection strategy.

    `name` is the canonical strategy name ("random", "power_of_choice",
    "s_fedavg", "ucb", "greedyfed", "greedyfed_dropout"); the remaining
    fields are the union of all strategies' hyperparameters (unused ones
    keep their defaults so specs stay comparable/hashable).
    """
    name: str
    n_clients: int
    m: int
    sv_mode: str = "mean"        # cumulative-SV averaging ("mean"|"exponential")
    sv_alpha: float = 0.5
    decay: float = 0.9           # power_of_choice: d decay rate
    d0: int = 0                  # power_of_choice: initial d, already
                                 # resolved (selector_spec maps the host's
                                 # None default to n_clients)
    c: float = 0.1               # ucb: exploration constant
    temperature: float = 1.0     # s_fedavg: softmax temperature
    drop_frac: float = 0.5       # greedyfed_dropout: fraction dropped

    @property
    def uses_shapley(self) -> bool:
        return self.name in ("s_fedavg", "ucb", "greedyfed",
                             "greedyfed_dropout")

    @property
    def uses_local_losses(self) -> bool:
        return self.name == "power_of_choice"

    @property
    def rr_rounds(self) -> int:
        return int(np.ceil(self.n_clients / self.m))

    @property
    def n_keep(self) -> int:
        """greedyfed_dropout: active-set size after the RR phase (>= m)."""
        return max(self.m, int(round((1.0 - self.drop_frac) * self.n_clients)))


class DeviceSelectorState(NamedTuple):
    """Fixed-shape selector state: a pytree for scan carries / seed vmaps."""
    valuation: ValuationState   # (N,) sv / counts / initialised
    round: jax.Array            # ()  int32 current round t
    rr_order: jax.Array         # (N,) int32 fixed random round-robin order
    active: jax.Array           # (N,) bool  dropout active-mask (all True
                                #            until greedyfed_dropout freezes)
    frozen: jax.Array           # ()  bool   has the active-mask been frozen


class DeviceSelectionContext(NamedTuple):
    """Per-round inputs any strategy may need (fixed shapes, zeros if unused)."""
    data_fractions: jax.Array   # (N,) q_k
    local_losses: jax.Array     # (N,) loss of w^t per client (Power-of-Choice)
    poc_d: jax.Array            # ()  int32 this round's candidate count d


def init_device_state(spec: SelectorSpec, seed: int = 0) -> DeviceSelectorState:
    """Mirror of `SelectorBase.init_state` (same host-rng rr_order draw)."""
    rng = np.random.default_rng(seed)
    return DeviceSelectorState(
        valuation=init_valuation(spec.n_clients),
        round=jnp.asarray(0, jnp.int32),
        rr_order=jnp.asarray(rng.permutation(spec.n_clients), jnp.int32),
        active=jnp.ones((spec.n_clients,), bool),
        frozen=jnp.asarray(False),
    )


# Runtime strategy registry: canonical name -> accepted kwargs + defaults.
# This is THE selector registry (the host classes in `core.selection` are a
# tests-only parity oracle); `STRATEGY_ALIASES` maps the paper's baseline
# names onto their canonical strategy.
_STRATEGY_KWARGS = {
    "random": {},
    "power_of_choice": {"decay": 0.9, "d0": None},
    "s_fedavg": {"beta": 0.5, "temperature": 1.0},
    "ucb": {"c": 0.1},
    "greedyfed": {"averaging": "mean", "alpha": 0.5},
    "greedyfed_dropout": {"averaging": "mean", "alpha": 0.5,
                          "drop_frac": 0.5},
}
STRATEGY_ALIASES = {
    "fedavg": "random",
    "fedprox": "random",   # the prox term lives in the client update
}


def strategy_names() -> list:
    """Every accepted `make_selector_spec` name (aliases included)."""
    return sorted(set(_STRATEGY_KWARGS) | set(STRATEGY_ALIASES))


def make_selector_spec(name: str, n_clients: int, m: int,
                       **kw) -> SelectorSpec:
    """Build a SelectorSpec from a registry name + selector kwargs.

    Accepts the same kwargs as the host oracle's `make_selector` for each
    strategy (PoC: decay/d0; S-FedAvg: beta/temperature; UCB: c; GreedyFed:
    averaging/alpha; dropout: + drop_frac), and the same registry names
    ("fedavg"/"fedprox" alias the canonical "random").  Raises ValueError
    listing the valid names on an unknown strategy.
    """
    canon = STRATEGY_ALIASES.get(name, name)
    try:
        accepted = _STRATEGY_KWARGS[canon]
    except KeyError:
        raise ValueError(f"unknown selector {name!r}; "
                         f"options: {strategy_names()}") from None
    bad = sorted(set(kw) - set(accepted))
    if bad:
        raise TypeError(f"selector {name!r} got unexpected kwargs {bad}; "
                        f"accepts {sorted(accepted)}")
    p = {**accepted, **kw}
    # d0 resolves to n_clients for every strategy (the host oracle's
    # None-means-N default), keeping specs comparable across factories
    spec = SelectorSpec(name=canon, n_clients=n_clients, m=m, d0=n_clients)
    if canon == "power_of_choice":
        # resolve the None-means-N default here so an explicit d0=0
        # (clamps to m every round) survives
        d0 = p["d0"]
        spec = spec._replace(decay=float(p["decay"]),
                             d0=int(d0) if d0 is not None else n_clients)
    elif canon == "s_fedavg":
        spec = spec._replace(sv_mode="exponential",
                             sv_alpha=float(p["beta"]),
                             temperature=float(p["temperature"]))
    elif canon == "ucb":
        spec = spec._replace(c=float(p["c"]))
    elif canon in ("greedyfed", "greedyfed_dropout"):
        spec = spec._replace(sv_mode=str(p["averaging"]),
                             sv_alpha=float(p["alpha"]))
        if canon == "greedyfed_dropout":
            spec = spec._replace(drop_frac=float(p["drop_frac"]))
    return spec


def poc_d_schedule(spec: SelectorSpec, rounds: int) -> np.ndarray:
    """(T,) int32 Power-of-Choice candidate counts, the host formula verbatim
    (python-float decay so device and host agree on every rounding)."""
    return np.asarray(
        [max(spec.m, int(round(spec.d0 * (spec.decay ** t))))
         for t in range(rounds)], np.int32)


# --------------------------------------------------------------------------
# shared score/probability helpers (the host selectors call these too,
# which is what makes host-vs-device selections bit-identical)
# --------------------------------------------------------------------------

def poc_probs(data_fractions: jax.Array) -> jax.Array:
    """Power-of-Choice candidate-sampling probabilities: normalised q_k."""
    p = jnp.asarray(data_fractions, jnp.float32)
    return p / jnp.sum(p)


def sfedavg_probs(val: ValuationState, temperature: float) -> jax.Array:
    """S-FedAvg selection probabilities: softmax over the EMA value vector.

    Unvalued clients get the mean value of valued ones (near-uniform early
    exploration); with nothing valued yet the raw (zero) vector is used.
    """
    init = val.initialised
    n_init = jnp.sum(init.astype(jnp.float32))
    mean_init = (jnp.sum(jnp.where(init, val.sv, 0.0))
                 / jnp.maximum(n_init, 1.0))
    sv = jnp.where(n_init > 0, jnp.where(init, val.sv, mean_init), val.sv)
    z = (sv - jnp.max(sv)) / max(temperature, 1e-8)
    p = jnp.exp(z)
    return p / jnp.sum(p)


def ucb_scores(val: ValuationState, round_t: jax.Array, c: float) -> jax.Array:
    """UCB acquisition: SV_k + c * sqrt(ln t / N_k) (t clipped at 2)."""
    counts = jnp.maximum(val.counts.astype(jnp.float32), 1.0)
    t = jnp.maximum(round_t, 2).astype(jnp.float32)
    return val.sv + c * jnp.sqrt(jnp.log(t) / counts)


def _gumbel_order(key: jax.Array, p: jax.Array) -> jax.Array:
    """(N,) full preference order of `jax.random.choice(..., replace=False,
    p=p)` — its Gumbel top-k internals verbatim; any without-replacement
    draw of size d from the same key is the first d entries."""
    g = -jax.random.gumbel(key, p.shape, p.dtype) - jnp.log(p)
    return jnp.argsort(g)


def _top_m(scores: jax.Array, m: int) -> jax.Array:
    """Indices of the m largest scores; ties resolve by client index
    (stable argsort — matches np.argsort(kind='stable') on the host)."""
    return jnp.argsort(-scores)[:m].astype(jnp.int32)


# --------------------------------------------------------------------------
# per-strategy select functions — identical signatures, fixed shapes
# --------------------------------------------------------------------------

def _rr_select(spec: SelectorSpec, state: DeviceSelectorState) -> jax.Array:
    """Alg. 1 lines 2-3: round-robin through the fixed random order."""
    idx = (state.round * spec.m + jnp.arange(spec.m)) % spec.n_clients
    return jnp.take(state.rr_order, idx).astype(jnp.int32)


def _sel_random(spec, state, key, ctx):
    sel = jax.random.choice(key, spec.n_clients, (spec.m,), replace=False)
    return sel.astype(jnp.int32), state


def _sel_power_of_choice(spec, state, key, ctx):
    # prefix property: candidates = first d of the full gumbel order
    order = _gumbel_order(key, poc_probs(ctx.data_fractions))
    cand_losses = jnp.take(ctx.local_losses, order)
    in_draw = jnp.arange(spec.n_clients) < ctx.poc_d
    masked = jnp.where(in_draw, cand_losses, -jnp.inf)
    sel = jnp.take(order, _top_m(masked, spec.m))
    return sel.astype(jnp.int32), state


def _sel_s_fedavg(spec, state, key, ctx):
    order = _gumbel_order(key, sfedavg_probs(state.valuation,
                                             spec.temperature))
    return order[: spec.m].astype(jnp.int32), state


def _sel_ucb(spec, state, key, ctx):
    top = _top_m(ucb_scores(state.valuation, state.round, spec.c), spec.m)
    sel = jnp.where(state.round < spec.rr_rounds, _rr_select(spec, state), top)
    return sel, state


def _sel_greedyfed(spec, state, key, ctx):
    top = _top_m(state.valuation.sv, spec.m)
    sel = jnp.where(state.round < spec.rr_rounds, _rr_select(spec, state), top)
    return sel, state


def _sel_greedyfed_dropout(spec, state, key, ctx):
    post_rr = state.round >= spec.rr_rounds
    # freeze the active set at the first post-RR selection: keep the top
    # n_keep by cumulative SV, drop the rest from the protocol for good
    rank = jnp.argsort(-state.valuation.sv)
    keep = jnp.zeros((spec.n_clients,), bool).at[rank[: spec.n_keep]].set(True)
    active = jnp.where(post_rr & ~state.frozen, keep, state.active)
    state = state._replace(active=active, frozen=state.frozen | post_rr)
    sv_masked = jnp.where(active, state.valuation.sv, -jnp.inf)
    sel = jnp.where(post_rr, _top_m(sv_masked, spec.m),
                    _rr_select(spec, state))
    return sel, state


_SELECT_FNS = {
    "random": _sel_random,
    "power_of_choice": _sel_power_of_choice,
    "s_fedavg": _sel_s_fedavg,
    "ucb": _sel_ucb,
    "greedyfed": _sel_greedyfed,
    "greedyfed_dropout": _sel_greedyfed_dropout,
}


# --------------------------------------------------------------------------
# public entry points
# --------------------------------------------------------------------------

def device_select(spec: SelectorSpec, state: DeviceSelectorState,
                  key: jax.Array, ctx: DeviceSelectionContext
                  ) -> tuple[jax.Array, DeviceSelectorState]:
    """Select the round's cohort: (sel (m,) int32, new state).  Pure and
    traceable; `spec` is static."""
    try:
        fn = _SELECT_FNS[spec.name]
    except KeyError:
        raise ValueError(f"unknown device selector {spec.name!r}; "
                         f"options: {sorted(_SELECT_FNS)}")
    return fn(spec, state, key, ctx)


def device_update(spec: SelectorSpec, state: DeviceSelectorState,
                  sel: jax.Array, sv_round: Optional[jax.Array] = None
                  ) -> DeviceSelectorState:
    """Post-round bookkeeping, mirroring `SelectorBase.update`.

    `sv_round` may be passed unconditionally (e.g. by a mixed-strategy
    switch whose engine always computes SV); strategies that do not value
    clients statically ignore it and only bump selection counts.
    """
    val = state.valuation
    if sv_round is not None and spec.uses_shapley:
        val = update_valuation(val, sel, sv_round, mode=spec.sv_mode,
                               alpha=spec.sv_alpha)
    else:
        val = ValuationState(
            sv=val.sv,
            counts=val.counts.at[sel].add(1),
            initialised=val.initialised.at[sel].set(True),
        )
    return state._replace(valuation=val, round=state.round + 1)


@functools.lru_cache(maxsize=64)
def jitted_selector(spec: SelectorSpec):
    """Compiled `(select, update)` pair for one spec, cached process-wide.

    The host-driven engines (`engine="loop"`/`"batched"`, and the
    per-round replica vmap) call selection once per round from python;
    jitting per spec keeps every round after the first a single cached
    executable launch instead of a retrace.
    """
    select = jax.jit(functools.partial(device_select, spec))
    update = jax.jit(functools.partial(device_update, spec))
    return select, update


def device_select_any(specs: tuple[SelectorSpec, ...], strategy_id: jax.Array,
                      state: DeviceSelectorState, key: jax.Array,
                      ctx: DeviceSelectionContext
                      ) -> tuple[jax.Array, DeviceSelectorState]:
    """`lax.switch` dispatch over a static tuple of specs with a *traced*
    strategy id — one compiled program serves a mixed-strategy replica
    batch.  All specs must share (n_clients, m) so shapes agree."""
    if len(specs) == 1:
        return device_select(specs[0], state, key, ctx)
    branches = [functools.partial(device_select, sp) for sp in specs]
    return jax.lax.switch(strategy_id, branches, state, key, ctx)


def device_update_any(specs: tuple[SelectorSpec, ...], strategy_id: jax.Array,
                      state: DeviceSelectorState, sel: jax.Array,
                      sv_round: Optional[jax.Array] = None
                      ) -> DeviceSelectorState:
    if len(specs) == 1:
        return device_update(specs[0], state, sel, sv_round)
    branches = [functools.partial(device_update, sp) for sp in specs]
    return jax.lax.switch(strategy_id, branches, state, sel, sv_round)


def gather_client_state(state: DeviceSelectorState, axis_name: str,
                        n_clients: int):
    """Client-axis-sharded selector state -> full state + a put_back fn.

    Inside a shard_map body over `axis_name` every per-client leaf of
    `state` (ndim >= 1: sv, counts, initialised, rr_order, active) is a
    local (N_pad / shards, ...) block; scalars (round, frozen) are
    replicated.  Selection itself is global — top-m over ALL clients —
    so the strategies run on the exact (N,) state:

        full, put_back = gather_client_state(state, axis_name, n)
        sel, full = device_select_any(specs, sid, full, key, ctx)
        full      = device_update_any(specs, sid, full, sel, sv)
        state     = put_back(full)

    `put_back` re-pads the updated (N,) leaves to (N_pad,) — the pad
    rows keep their (constant) initial values, so they stay deterministic
    across rounds — and slices this shard's block back out.  All leaves
    round-trip bitwise: gather/slice copies bits, and the strategies
    never read or write pad rows.
    """
    full_pad = jax.tree.map(
        lambda x: jax.lax.all_gather(x, axis_name, tiled=True)
        if x.ndim >= 1 else x, state)
    full = jax.tree.map(lambda x: x[:n_clients] if x.ndim >= 1 else x,
                        full_pad)
    idx = jax.lax.axis_index(axis_name)

    def put_back(new_full: DeviceSelectorState) -> DeviceSelectorState:
        def scatter(loc, pad, new):
            if new.ndim == 0:
                return new
            merged = jax.lax.dynamic_update_slice_in_dim(pad, new, 0, 0)
            n_local = loc.shape[0]
            return jax.lax.dynamic_slice_in_dim(merged, idx * n_local,
                                                n_local, 0)
        return jax.tree.map(scatter, state, full_pad, new_full)

    return full, put_back


def device_dropped_fraction(state: DeviceSelectorState) -> jax.Array:
    """Fraction of clients dropped from the protocol (0 until frozen)."""
    return jnp.where(state.frozen,
                     1.0 - jnp.mean(state.active.astype(jnp.float32)), 0.0)

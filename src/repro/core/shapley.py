"""GTG-Shapley (Alg. 2) — server-side fast Shapley-Value approximation.

Monte-Carlo permutation sampling with two truncations:
  * between-round: if |U(w^{t+1}) - U(w^t)| < eps, all SVs are zero this round;
  * within-round: while scanning a permutation, once |v_M - v_j| < eps the
    remaining marginal contributions are taken as zero (v carried forward).

The implementation is a `lax.while_loop` (outer MC iterations, with the
GTG default convergence criterion: relative change of the SV estimate)
around a `lax.scan` over the M starting clients, around a `lax.scan` over
permutation positions whose body uses `lax.cond` — so within-round
truncation genuinely skips the utility evaluation at runtime (cond executes
a single branch when not vmapped), matching the paper's tractability claim.

Utility U(S) = utility_fn(ModelAverage over subset S), with the empty subset
mapped to the previous server model w^t (v_0).
"""
from __future__ import annotations

import itertools
import math
from functools import partial
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.aggregation import subset_average

PyTree = Any
UtilityFn = Callable[[PyTree], jax.Array]  # pytree params -> scalar utility


class ShapleyStats(NamedTuple):
    # MC rounds (serial) / permutations (batched, streaming) actually
    # walked — 0 when between-round truncation skipped the whole MC run
    iterations: jax.Array
    utility_evals: jax.Array   # number of non-truncated utility evaluations
    v0: jax.Array              # U(w^t)
    vM: jax.Array              # U(w^{t+1})
    truncated_round: jax.Array  # bool: between-round truncation fired


def _permutation_batch(key: jax.Array, m: int) -> jax.Array:
    """(M, M) int32: row k is a permutation of [M] with first element k."""
    def one(k, subkey):
        others = jnp.delete(jnp.arange(m), k, assume_unique_indices=True)
        rest = jax.random.permutation(subkey, others)
        return jnp.concatenate([jnp.array([k]), rest])

    keys = jax.random.split(key, m)
    return jax.vmap(one)(jnp.arange(m), keys)


@partial(jax.jit, static_argnames=("utility_fn", "max_iters"))
def gtg_shapley(
    stacked_updates: PyTree,
    n_k: jax.Array,
    w_prev: PyTree,
    utility_fn: UtilityFn,
    key: jax.Array,
    *,
    eps: float = 1e-4,
    max_iters: int | None = None,
    convergence_tol: float = 0.05,
    convergence_rounds: int = 3,
) -> tuple[jax.Array, ShapleyStats]:
    """Approximate SV of each of the M stacked client updates.

    stacked_updates: pytree with leaves (M, *shape) — client models w_k^{t+1}.
    n_k: (M,) dataset sizes for ModelAverage weights.
    Returns (sv: (M,) float32, stats).
    """
    m = n_k.shape[0]
    if max_iters is None:
        max_iters = 50 * m  # paper: T = 50 * |S|

    w_full = subset_average(stacked_updates, n_k, jnp.ones((m,)))
    v0 = utility_fn(w_prev)
    v_m = utility_fn(w_full)

    def subset_utility(mask: jax.Array) -> jax.Array:
        return utility_fn(subset_average(stacked_updates, n_k, mask))

    def perm_walk(perm: jax.Array):
        """Scan one permutation; return per-client marginal contributions."""

        def step(carry, j):
            v_j, mask, n_evals = carry
            mask = mask.at[perm[j]].set(1.0)
            truncate = jnp.abs(v_m - v_j) < eps

            v_next = jax.lax.cond(
                truncate,
                lambda: v_j,                      # within-round truncation
                lambda: subset_utility(mask),
            )
            n_evals = n_evals + jnp.where(truncate, 0, 1)
            marginal = v_next - v_j
            return (v_next, mask, n_evals), (perm[j], marginal)

        init = (v0, jnp.zeros((m,)), jnp.array(0, jnp.int32))
        (_, _, n_evals), (idx, marg) = jax.lax.scan(step, init, jnp.arange(m))
        # scatter marginals back to client slots
        contrib = jnp.zeros((m,)).at[idx].add(marg)
        return contrib, n_evals

    def mc_round(carry):
        sv_sum, count, tau, key, _, n_evals, sv_prev, stall = carry
        key, sub = jax.random.split(key)
        perms = _permutation_batch(sub, m)

        def body(acc, perm):
            contrib, ne = perm_walk(perm)
            return (acc[0] + contrib, acc[1] + ne), None

        (round_contrib, round_evals), _ = jax.lax.scan(
            body, (jnp.zeros((m,)), jnp.array(0, jnp.int32)), perms
        )
        sv_sum = sv_sum + round_contrib
        count = count + m  # each round contributes one marginal per client per perm
        tau = tau + 1
        sv_now = sv_sum / jnp.maximum(count, 1)
        denom = jnp.maximum(jnp.max(jnp.abs(sv_now)), eps)
        rel_change = jnp.max(jnp.abs(sv_now - sv_prev)) / denom
        stall = jnp.where(rel_change < convergence_tol, stall + 1, 0)
        converged = stall >= convergence_rounds
        return (sv_sum, count, tau, key, converged, n_evals + round_evals, sv_now, stall)

    def cond(carry):
        _, _, tau, _, converged, _, _, _ = carry
        return jnp.logical_and(tau < max_iters, jnp.logical_not(converged))

    init = (
        jnp.zeros((m,)), jnp.zeros((m,), jnp.int32), jnp.array(0, jnp.int32),
        key, jnp.array(False), jnp.array(0, jnp.int32), jnp.zeros((m,)),
        jnp.array(0, jnp.int32),
    )

    def run_mc():
        sv_sum, count, tau, _, _, n_evals, _, _ = jax.lax.while_loop(cond, mc_round, init)
        sv = sv_sum / jnp.maximum(count, 1)
        return sv, tau, n_evals

    def skip_mc():  # between-round truncation
        return jnp.zeros((m,)), jnp.array(0, jnp.int32), jnp.array(0, jnp.int32)

    between_trunc = jnp.abs(v_m - v0) < eps
    sv, tau, n_evals = jax.lax.cond(between_trunc, skip_mc, run_mc)

    stats = ShapleyStats(
        iterations=tau, utility_evals=n_evals + 2, v0=v0, vM=v_m,
        truncated_round=between_trunc,
    )
    return sv, stats


def exact_shapley(
    stacked_updates: PyTree,
    n_k: jax.Array,
    w_prev: PyTree,
    utility_fn: UtilityFn,
) -> jax.Array:
    """Brute-force SV over all 2^M subsets (test oracle; M <= ~10)."""
    m = int(n_k.shape[0])

    def u_of_mask(mask_tuple):
        mask = jnp.asarray(mask_tuple, jnp.float32)
        if not any(mask_tuple):
            return float(utility_fn(w_prev))
        return float(utility_fn(subset_average(stacked_updates, n_k, mask)))

    cache: dict[tuple, float] = {}
    def u(mask_tuple):
        if mask_tuple not in cache:
            cache[mask_tuple] = u_of_mask(mask_tuple)
        return cache[mask_tuple]

    sv = [0.0] * m
    for k in range(m):
        others = [i for i in range(m) if i != k]
        for r in range(m):
            for subset in itertools.combinations(others, r):
                base = tuple(1 if i in subset else 0 for i in range(m))
                with_k = tuple(1 if (i in subset or i == k) else 0 for i in range(m))
                weight = 1.0 / (m * math.comb(m - 1, r))
                sv[k] += weight * (u(with_k) - u(base))
    return jnp.asarray(sv)

"""Weighted model aggregation — the ModelAverage subroutine of GreedyFed.

The Shapley hot-spot: GTG-Shapley (Alg. 2) evaluates O(T_mc * M^2) subset
averages per communication round.  We therefore keep the M selected clients'
updates *stacked* along a leading client axis (one pytree whose leaves have
shape (M, *param_shape)) and express every subset average as a masked
weighted reduction over that axis.  This fuses into a single multiply-reduce
per leaf (and, on TPU, into the `kernels/weighted_avg` Pallas kernel).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any


def tree_stack(trees: list[PyTree]) -> PyTree:
    """Stack a list of identically-structured pytrees along a new axis 0."""
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *trees)


def tree_unstack(stacked: PyTree, n: int) -> list[PyTree]:
    return [jax.tree.map(lambda x: x[i], stacked) for i in range(n)]


def normalized_weights(n_k: jax.Array, mask: jax.Array | None = None) -> jax.Array:
    """lambda_k proportional to n_k over the masked subset, summing to 1.

    n_k: (M,) client dataset sizes.  mask: (M,) {0,1} subset indicator.
    Empty subsets return all-zero weights (caller handles via utility of w^t).
    """
    n_k = jnp.asarray(n_k, jnp.float32)
    if mask is not None:
        n_k = n_k * mask.astype(jnp.float32)
    total = jnp.sum(n_k)
    return jnp.where(total > 0, n_k / jnp.maximum(total, 1e-12), jnp.zeros_like(n_k))


def weighted_average(stacked: PyTree, weights: jax.Array) -> PyTree:
    """ModelAverage(n_k, w_k): sum_k weights[k] * leaf[k] for every leaf.

    `weights` must already be normalised (see `normalized_weights`).
    """
    def _avg(leaf: jax.Array) -> jax.Array:
        w = weights.reshape((-1,) + (1,) * (leaf.ndim - 1)).astype(leaf.dtype)
        return jnp.sum(leaf * w, axis=0)

    return jax.tree.map(_avg, stacked)


def subset_average(stacked: PyTree, n_k: jax.Array, mask: jax.Array) -> PyTree:
    """ModelAverage restricted to the subset indicated by `mask` (M,) in {0,1}."""
    return weighted_average(stacked, normalized_weights(n_k, mask))


def model_average(models: list[PyTree], n_k) -> PyTree:
    """Convenience non-stacked entry point (server aggregation, Alg. 1 line 9)."""
    return weighted_average(tree_stack(models), normalized_weights(jnp.asarray(n_k)))


def tree_add(a: PyTree, b: PyTree) -> PyTree:
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a: PyTree, b: PyTree) -> PyTree:
    return jax.tree.map(jnp.subtract, a, b)


def tree_scale(a: PyTree, s) -> PyTree:
    return jax.tree.map(lambda x: x * s, a)


def tree_dot(a: PyTree, b: PyTree) -> jax.Array:
    parts = jax.tree.leaves(jax.tree.map(lambda x, y: jnp.vdot(x, y), a, b))
    return functools.reduce(jnp.add, parts)


def tree_sq_norm(a: PyTree) -> jax.Array:
    return tree_dot(a, a)


def tree_zeros_like(a: PyTree) -> PyTree:
    return jax.tree.map(jnp.zeros_like, a)


def tree_cast(a: PyTree, dtype) -> PyTree:
    return jax.tree.map(lambda x: x.astype(dtype), a)


def tree_size(a: PyTree) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(a))

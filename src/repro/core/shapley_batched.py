"""Batched GTG-Shapley — the TPU-native adaptations (DESIGN.md §8, §14).

Alg. 2 as published is *serial*: it truncates inside each permutation walk,
saving utility evals at the cost of a sequential dependency chain.  On TPU
the economics invert: evaluating EVERY prefix subset of R permutations in
one pass amortises the HBM read of the stacked client models, and the
`ce_loss` kernel evaluates all resulting models' utilities in one batched
forward.  Two device estimators share that structure:

  * `gtg_shapley_batched` (§8, the dense oracle) — materialises the
    (R*M, M) prefix-weight matrix and contracts it against the stacked
    updates with the `weighted_avg` kernel: O(R*M^2*D) FLOPs and all
    R*M prefix models resident at once.
  * `gtg_shapley_streaming` (§14, the default) — exploits that along a
    walk the prefix ModelAverage is a running sum
    (S_j = S_{j-1} + n_{pi(j)} w_{pi(j)}, wbar_j = S_j / N_j): the
    `prefix_avg` kernel gathers client rows in walk order and
    cumulative-sums them per D-block — O(R*M*D) FLOPs, an M-fold
    reduction — and an optional chunked evaluator (`sv_chunk`) walks the
    permutations `lax.map`-wise so peak memory is O(chunk * D) instead
    of all R*M models.

Both draw the SAME permutations from the same key (`_draw_perms`), so they
compute the same Monte-Carlo average and differ only in floating-point
association; `tests/test_shapley.py` pins streaming == dense at f32
tolerance and chunked == unchunked bitwise.  Between-round truncation
(|v_M - v_0| < eps) is kept (it gates the whole round); within-round
truncation is dropped — its savings are recovered by bandwidth
amortisation.  The estimator is the same Monte-Carlo permutation average,
so it converges to the identical SV (checked against the exact oracle).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core.aggregation import subset_average
from repro.core.shapley import ShapleyStats, _permutation_batch

PyTree = Any


def prefix_weight_matrix(perms: jax.Array, n_k: jax.Array) -> jax.Array:
    """(R, M) permutations -> (R, M, M) normalised prefix-subset weights.

    Row (r, j) holds ModelAverage weights for the subset perms[r, :j+1].
    """
    r, m = perms.shape
    onehot = jax.nn.one_hot(perms, m)                    # (R, M, M)
    prefix_mask = jnp.cumsum(onehot, axis=1)             # (R, j, M) in {0,1}
    w = prefix_mask * n_k[None, None, :]
    return w / jnp.maximum(w.sum(-1, keepdims=True), 1e-12)


def _draw_perms(key: jax.Array, m: int, n_perms: int) -> jax.Array:
    """(R, M) permutation walks, shared by the dense and streaming paths.

    Balanced sampling: draw whole (M, M) batches (each client first
    exactly once per batch) so first-position marginals are stratified —
    strictly lower variance than R independent permutations.  The row
    shuffle keeps truncation to n_perms unbiased when n_perms % M != 0
    (otherwise low-index clients would always keep their first-position
    rows and high-index clients never would).  Identical key discipline on
    both estimators => identical walks => they differ only in
    floating-point association.
    """
    n_batches = -(-n_perms // m)
    bkey, skey = jax.random.split(key)
    keys = jax.random.split(bkey, n_batches)
    perms = jax.vmap(lambda k: _permutation_batch(k, m))(keys)
    perms = perms.reshape(n_batches * m, m)
    return jax.random.permutation(skey, perms, axis=0)[:n_perms]


def _walk_sv(vs: jax.Array, perms: jax.Array, v0: jax.Array,
             n_perms: int, m: int) -> jax.Array:
    """(R, M) walk utilities -> (M,) SV: marginals along each walk,
    scattered back to client slots and averaged over permutations."""
    v_prev = jnp.concatenate(
        [jnp.full((n_perms, 1), v0), vs[:, :-1]], axis=1)
    marginals = vs - v_prev                              # (R, M) along walk
    return jnp.zeros((m,)).at[perms.reshape(-1)].add(
        marginals.reshape(-1)) / n_perms


def _round_stats(truncated: jax.Array, n_evals: jax.Array, n_perms: int,
                 v0: jax.Array, v_m: jax.Array) -> ShapleyStats:
    """Stats shared by both device estimators.  `iterations` reports the
    permutations actually walked — 0 when between-round truncation skipped
    the whole MC run (pinned in tests/test_shapley.py)."""
    return ShapleyStats(
        iterations=jnp.where(truncated, 0, n_perms).astype(jnp.int32),
        utility_evals=n_evals + 2, v0=v0, vM=v_m, truncated_round=truncated)


@partial(jax.jit, static_argnames=("batched_utility_fn", "utility_fn",
                                   "n_perms", "use_kernel"))
def gtg_shapley_batched(
    stacked_updates: PyTree,
    n_k: jax.Array,
    w_prev: PyTree,
    utility_fn: Callable[[PyTree], jax.Array],
    batched_utility_fn: Callable[[PyTree], jax.Array],
    key: jax.Array,
    *,
    eps: float = 1e-4,
    n_perms: int = 64,
    use_kernel: bool = True,
) -> tuple[jax.Array, ShapleyStats]:
    """Dense SV estimate: all R*M prefix models in one contraction (§8).

    Kept as the parity oracle for `gtg_shapley_streaming`; the engines
    reach it via `shapley_impl="batched"`.
    batched_utility_fn: pytree with leaves (R*, ...) -> (R*,) utilities.
    """
    m = n_k.shape[0]
    w_full = subset_average(stacked_updates, n_k, jnp.ones((m,)))
    v0 = utility_fn(w_prev)
    v_m = utility_fn(w_full)

    def run():
        perms = _draw_perms(key, m, n_perms)              # (R, M)
        weights = prefix_weight_matrix(perms, n_k)        # (R, M, M)
        flat_w = weights.reshape(n_perms * m, m)          # (R*M, M)

        if use_kernel:
            from repro.kernels.weighted_avg.ops import weighted_avg
            models = weighted_avg(stacked_updates, flat_w)
        else:
            models = jax.vmap(
                lambda w: jax.tree.map(
                    lambda leaf: jnp.tensordot(w.astype(leaf.dtype), leaf, 1),
                    stacked_updates))(flat_w)

        vs = batched_utility_fn(models).reshape(n_perms, m)
        sv = _walk_sv(vs, perms, v0, n_perms, m)
        return sv, jnp.array(n_perms * m, jnp.int32)

    def skip():
        return jnp.zeros((m,)), jnp.array(0, jnp.int32)

    truncated = jnp.abs(v_m - v0) < eps
    sv, n_evals = jax.lax.cond(truncated, skip, run)
    return sv, _round_stats(truncated, n_evals, n_perms, v0, v_m)


@partial(jax.jit, static_argnames=("batched_utility_fn", "utility_fn",
                                   "n_perms", "sv_chunk", "use_kernel"))
def gtg_shapley_streaming(
    stacked_updates: PyTree,
    n_k: jax.Array,
    w_prev: PyTree,
    utility_fn: Callable[[PyTree], jax.Array],
    batched_utility_fn: Callable[[PyTree], jax.Array],
    key: jax.Array,
    *,
    eps: float = 1e-4,
    n_perms: int = 64,
    sv_chunk: int = 0,
    use_kernel: bool = True,
) -> tuple[jax.Array, ShapleyStats]:
    """Streaming SV estimate: incremental prefix walks (§14, the default).

    Same Monte-Carlo average as `gtg_shapley_batched` over the same
    permutations, but prefix models come from the `prefix_avg` running-sum
    kernel (O(R*M*D) FLOPs, an M-fold reduction over the dense path) and
    utilities are evaluated `sv_chunk` models at a time:

      sv_chunk = c > 0  — `lax.map` over ceil(c / M)-walk chunks, peak
                          model memory O(max(c, M) * D);
      sv_chunk = 0      — auto (the default): one walk (M models) per
                          step off-TPU, where the chunk staying
                          cache-resident beats the dense matmul ~2x
                          (BENCH_shapley.json); all R*M on TPU, where the
                          kernel streams construction anyway and the full
                          batch keeps the utility evals wide for the MXU;
      sv_chunk < 0      — force the single all-resident pass.

    Chunking is numerics-invariant: boundaries fall on whole walks and
    the walk accumulation is strictly left-to-right, so every chunking —
    auto included — is bit-identical (pinned in tests/test_shapley.py).
    """
    m = int(n_k.shape[0])
    w_full = subset_average(stacked_updates, n_k, jnp.ones((m,)))
    v0 = utility_fn(w_prev)
    v_m = utility_fn(w_full)

    if sv_chunk == 0:   # auto, resolved at trace time
        chunk_walks = 1 if jax.default_backend() != "tpu" else n_perms
    elif sv_chunk < 0:
        chunk_walks = n_perms
    else:
        chunk_walks = min(max(1, -(-sv_chunk // m)), n_perms)
    n_chunks = -(-n_perms // chunk_walks)
    pad_walks = n_chunks * chunk_walks - n_perms

    def run():
        from repro.kernels.prefix_avg.ops import prefix_avg

        perms = _draw_perms(key, m, n_perms)              # (R, M)
        if pad_walks:
            filler = jnp.tile(jnp.arange(m, dtype=perms.dtype)[None, :],
                              (pad_walks, 1))
            perms_padded = jnp.concatenate([perms, filler], axis=0)
        else:
            perms_padded = perms

        def eval_chunk(perm_chunk):                       # (c, M) walks
            models = prefix_avg(stacked_updates, perm_chunk, n_k,
                                use_kernel=use_kernel)
            return batched_utility_fn(models)             # (c*M,)

        if n_chunks == 1:
            vs = eval_chunk(perms_padded)
        else:
            vs = jax.lax.map(
                eval_chunk,
                perms_padded.reshape(n_chunks, chunk_walks, m))
            vs = vs.reshape(-1)[: n_perms * m]
        sv = _walk_sv(vs.reshape(n_perms, m), perms, v0, n_perms, m)
        # honest accounting: filler walks of a non-dividing chunk are
        # evaluated too (their utilities are just discarded)
        return sv, jnp.array(n_chunks * chunk_walks * m, jnp.int32)

    def skip():
        return jnp.zeros((m,)), jnp.array(0, jnp.int32)

    truncated = jnp.abs(v_m - v0) < eps
    sv, n_evals = jax.lax.cond(truncated, skip, run)
    return sv, _round_stats(truncated, n_evals, n_perms, v0, v_m)


def make_batched_mlp_utility(model, x_val: jax.Array, y_val: jax.Array):
    """vmapped -(val CE) over a batch of parameter pytrees, using the fused
    ce_loss kernel for the per-model loss."""
    from repro.kernels.ce_loss.ops import ce_loss

    def one(params):
        logits = model.apply(params, x_val)
        return -ce_loss(logits, y_val)

    return jax.vmap(one)

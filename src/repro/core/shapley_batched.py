"""Batched GTG-Shapley — the TPU-native adaptation (DESIGN.md §3).

Alg. 2 as published is *serial*: it truncates inside each permutation walk,
saving utility evals at the cost of a sequential dependency chain.  On TPU
the economics invert: one pass of the fused `weighted_avg` kernel evaluates
EVERY prefix subset of R permutations against a single HBM read of the
stacked client models, and the `ce_loss` kernel evaluates all resulting
models' utilities in one batched forward.

    serial GTG:   O(T * M^2) kernel launches, each re-reading W (M, D)
    batched GTG:  ceil(T/R) passes, W read once per pass

Between-round truncation (|v_M - v_0| < eps) is kept (it gates the whole
round); within-round truncation is dropped — its savings are recovered by
bandwidth amortisation.  The estimator is the same Monte-Carlo permutation
average, so it converges to the identical SV (tests/test_shapley.py checks
both against the exact oracle).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core.aggregation import normalized_weights, subset_average
from repro.core.shapley import ShapleyStats, _permutation_batch

PyTree = Any


def prefix_weight_matrix(perms: jax.Array, n_k: jax.Array) -> jax.Array:
    """(R, M) permutations -> (R, M, M) normalised prefix-subset weights.

    Row (r, j) holds ModelAverage weights for the subset perms[r, :j+1].
    """
    r, m = perms.shape
    onehot = jax.nn.one_hot(perms, m)                    # (R, M, M)
    prefix_mask = jnp.cumsum(onehot, axis=1)             # (R, j, M) in {0,1}
    w = prefix_mask * n_k[None, None, :]
    return w / jnp.maximum(w.sum(-1, keepdims=True), 1e-12)


@partial(jax.jit, static_argnames=("batched_utility_fn", "utility_fn",
                                   "n_perms", "use_kernel"))
def gtg_shapley_batched(
    stacked_updates: PyTree,
    n_k: jax.Array,
    w_prev: PyTree,
    utility_fn: Callable[[PyTree], jax.Array],
    batched_utility_fn: Callable[[PyTree], jax.Array],
    key: jax.Array,
    *,
    eps: float = 1e-4,
    n_perms: int = 64,
    use_kernel: bool = True,
) -> tuple[jax.Array, ShapleyStats]:
    """SV estimate from `n_perms` permutations evaluated in one batch.

    batched_utility_fn: pytree with leaves (R*, ...) -> (R*,) utilities.
    """
    m = n_k.shape[0]
    w_full = subset_average(stacked_updates, n_k, jnp.ones((m,)))
    v0 = utility_fn(w_prev)
    v_m = utility_fn(w_full)

    def run():
        # Balanced sampling: draw whole (M, M) batches (each client first
        # exactly once per batch) so first-position marginals are stratified
        # — strictly lower variance than R independent permutations.  The
        # row shuffle keeps truncation to n_perms unbiased when
        # n_perms % M != 0 (otherwise low-index clients would always keep
        # their first-position rows and high-index clients never would).
        n_batches = -(-n_perms // m)
        bkey, skey = jax.random.split(key)
        keys = jax.random.split(bkey, n_batches)
        perms = jax.vmap(lambda k: _permutation_batch(k, m))(keys)
        perms = perms.reshape(n_batches * m, m)
        perms = jax.random.permutation(skey, perms, axis=0)[:n_perms]  # (R, M)
        weights = prefix_weight_matrix(perms, n_k)        # (R, M, M)
        flat_w = weights.reshape(n_perms * m, m)          # (R*M, M)

        if use_kernel:
            from repro.kernels.weighted_avg.ops import weighted_avg
            models = weighted_avg(stacked_updates, flat_w)
        else:
            models = jax.vmap(
                lambda w: jax.tree.map(
                    lambda leaf: jnp.tensordot(w.astype(leaf.dtype), leaf, 1),
                    stacked_updates))(flat_w)

        vs = batched_utility_fn(models).reshape(n_perms, m)
        v_prev = jnp.concatenate(
            [jnp.full((n_perms, 1), v0), vs[:, :-1]], axis=1)
        marginals = vs - v_prev                           # (R, M) along walk
        sv = jnp.zeros((m,)).at[perms.reshape(-1)].add(
            marginals.reshape(-1)) / n_perms
        return sv, jnp.array(n_perms * m, jnp.int32)

    def skip():
        return jnp.zeros((m,)), jnp.array(0, jnp.int32)

    truncated = jnp.abs(v_m - v0) < eps
    sv, n_evals = jax.lax.cond(truncated, skip, run)
    stats = ShapleyStats(
        iterations=jnp.array(n_perms, jnp.int32),
        utility_evals=n_evals + 2, v0=v0, vM=v_m, truncated_round=truncated)
    return sv, stats


def make_batched_mlp_utility(model, x_val: jax.Array, y_val: jax.Array):
    """vmapped -(val CE) over a batch of parameter pytrees, using the fused
    ce_loss kernel for the per-model loss."""
    from repro.kernels.ce_loss.ops import ce_loss

    def one(params):
        logits = model.apply(params, x_val)
        return -ce_loss(logits, y_val)

    return jax.vmap(one)

"""Cumulative Shapley-Value tracking (Alg. 1, lines 11-12).

Two variants from the paper:
  * mean:        SV_k <- ((N_k - 1) SV_k + SV_k^(t)) / N_k
  * exponential: SV_k <- alpha * SV_k + (1 - alpha) * SV_k^(t)
where N_k counts how many times client k has been selected, and updates only
apply to clients in S_t (mean over rounds where the client participated —
the S-FedAvg/UCB convention the paper borrows).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class ValuationState(NamedTuple):
    sv: jax.Array        # (N,) cumulative Shapley value per client
    counts: jax.Array    # (N,) number of times each client was selected
    initialised: jax.Array  # (N,) bool — has the client ever been valued


def init_valuation(n_clients: int) -> ValuationState:
    return ValuationState(
        sv=jnp.zeros((n_clients,), jnp.float32),
        counts=jnp.zeros((n_clients,), jnp.int32),
        initialised=jnp.zeros((n_clients,), bool),
    )


def update_valuation(
    state: ValuationState,
    selected: jax.Array,      # (M,) int client indices of S_t
    sv_round: jax.Array,      # (M,) SV_k^(t) from GTG-Shapley
    *,
    mode: str = "mean",       # "mean" | "exponential"
    alpha: float = 0.5,
) -> ValuationState:
    counts = state.counts.at[selected].add(1)
    if mode == "mean":
        n_sel = counts[selected].astype(jnp.float32)
        new_vals = ((n_sel - 1.0) * state.sv[selected] + sv_round) / n_sel
    elif mode == "exponential":
        first = ~state.initialised[selected]
        ema = alpha * state.sv[selected] + (1.0 - alpha) * sv_round
        new_vals = jnp.where(first, sv_round, ema)
    else:
        raise ValueError(f"unknown valuation mode: {mode!r}")
    return ValuationState(
        sv=state.sv.at[selected].set(new_vals),
        counts=counts,
        initialised=state.initialised.at[selected].set(True),
    )

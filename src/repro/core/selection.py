"""Client selection strategies — the TESTS-ONLY host parity oracle.

This module is NOT on any runtime path (DESIGN.md §13): every engine
selects through `repro.core.selection_jax`, the single runtime selector
implementation, and the only importer of this file is
`tests/test_selection.py`.  The classes survive as an independently
written reference whose per-round selections the device stack must
reproduce bit-for-bit.

Common interface (python-level orchestration; inner math is jnp):

    strategy = GreedyFedSelector(n_clients=N, m=M)
    sel, state = strategy.select(state, key, ctx)
    state = strategy.update(state, sel, sv_round=...)

`ctx` is a SelectionContext carrying everything any strategy may need
(data fractions, local losses of the current global model, ...).

Parity mechanics: scores and sampling probabilities are computed with the
shared jnp helpers of `selection_jax` and all top-M cuts use stable
argsorts, so a host selector and its device twin produce bit-identical
selections from the same key (tests/test_selection.py pins this for every
registry entry x 2 seeds).

Implemented strategies (paper Section IV baselines + ours):
  * RandomSelector           — FedAvg / FedProx uniform sampling
  * PowerOfChoiceSelector    — [7]: query d candidates, pick M highest-loss,
                               d decaying exponentially (rate 0.9)
  * SFedAvgSelector          — [13]: softmax sampling over EMA value vector
  * UCBSelector              — [12]: RR init, then top-M of SV + UCB bonus
  * GreedyFedSelector        — ours (Alg. 1): RR init, then top-M cumulative SV
  * CentralizedSelector      — degenerate upper bound (server holds all data)
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.selection_jax import (
    SelectorSpec, poc_probs, sfedavg_probs, ucb_scores,
)
from repro.core.valuation import ValuationState, init_valuation, update_valuation


class SelectionContext(NamedTuple):
    data_fractions: jax.Array                 # (N,) q_k
    local_losses: Optional[jax.Array] = None  # (N,) loss of w^t on each client's data (Power-of-Choice)


class SelectorState(NamedTuple):
    valuation: ValuationState
    round: int
    rr_order: np.ndarray      # random round-robin order fixed at init
    active: np.ndarray        # (N,) bool dropout active-mask (fixed shape;
                              # all True until greedyfed_dropout freezes it)
    frozen: bool              # has the active-mask been frozen


@dataclasses.dataclass
class SelectorBase:
    n_clients: int
    m: int
    seed: int = 0

    name = "base"
    uses_shapley = False
    uses_local_losses = False

    def init_state(self) -> SelectorState:
        rng = np.random.default_rng(self.seed)
        return SelectorState(
            valuation=init_valuation(self.n_clients),
            round=0,
            rr_order=rng.permutation(self.n_clients),
            active=np.ones(self.n_clients, bool),
            frozen=False,
        )

    # -- helpers ---------------------------------------------------------
    def _rr_rounds(self) -> int:
        return int(np.ceil(self.n_clients / self.m))

    def _rr_select(self, state: SelectorState) -> np.ndarray:
        """Alg. 1 lines 2-3: round-robin in a fixed random order."""
        start = state.round * self.m
        idx = [(start + i) % self.n_clients for i in range(self.m)]
        return state.rr_order[idx]

    def select(self, state: SelectorState, key: jax.Array,
               ctx: SelectionContext) -> tuple[np.ndarray, SelectorState]:
        raise NotImplementedError

    def update(self, state: SelectorState, selected: np.ndarray,
               sv_round: Optional[jax.Array] = None) -> SelectorState:
        """Post-round bookkeeping; default just counts selections."""
        val = state.valuation
        if sv_round is not None:
            val = update_valuation(val, jnp.asarray(selected), sv_round,
                                   mode=self.sv_mode(), alpha=self.sv_alpha())
        else:
            val = ValuationState(
                sv=val.sv,
                counts=val.counts.at[jnp.asarray(selected)].add(1),
                initialised=val.initialised.at[jnp.asarray(selected)].set(True),
            )
        return state._replace(valuation=val, round=state.round + 1)

    def sv_mode(self) -> str:
        return "mean"

    def sv_alpha(self) -> float:
        return 0.5


@dataclasses.dataclass
class RandomSelector(SelectorBase):
    """FedAvg / FedProx: uniform random sampling without replacement."""
    name = "random"

    def select(self, state, key, ctx):
        sel = jax.random.choice(key, self.n_clients, (self.m,), replace=False)
        return np.asarray(sel), state


@dataclasses.dataclass
class PowerOfChoiceSelector(SelectorBase):
    """[7]: sample d candidates (prob ∝ q_k), pick the M with highest local loss.

    d starts at d0 (default N) and decays by `decay` each round toward M.
    """
    decay: float = 0.9
    d0: Optional[int] = None

    name = "power_of_choice"
    uses_local_losses = True

    def select(self, state, key, ctx):
        assert ctx.local_losses is not None, "Power-of-Choice needs local losses"
        d0 = self.d0 if self.d0 is not None else self.n_clients
        d = max(self.m, int(round(d0 * (self.decay ** state.round))))
        cand = jax.random.choice(key, self.n_clients, (d,), replace=False,
                                 p=poc_probs(ctx.data_fractions))
        cand = np.asarray(cand)
        losses = np.asarray(ctx.local_losses)[cand]
        top = cand[np.argsort(-losses, kind="stable")[: self.m]]
        return top, state


@dataclasses.dataclass
class SFedAvgSelector(SelectorBase):
    """[13]: selection probabilities = softmax over EMA'd cumulative SV."""
    beta: float = 0.5          # EMA on value vector
    temperature: float = 1.0

    name = "s_fedavg"
    uses_shapley = True

    def sv_mode(self) -> str:
        return "exponential"

    def sv_alpha(self) -> float:
        return self.beta

    def select(self, state, key, ctx):
        p = sfedavg_probs(state.valuation, self.temperature)
        sel = jax.random.choice(key, self.n_clients, (self.m,), replace=False,
                                p=p)
        return np.asarray(sel), state


@dataclasses.dataclass
class UCBSelector(SelectorBase):
    """[12]: RR initialisation, then top-M of SV_k + c*sqrt(ln t / N_k)."""
    c: float = 0.1

    name = "ucb"
    uses_shapley = True

    def select(self, state, key, ctx):
        if state.round < self._rr_rounds():
            return self._rr_select(state), state
        scores = np.asarray(ucb_scores(state.valuation, state.round, self.c))
        return np.argsort(-scores, kind="stable")[: self.m], state


@dataclasses.dataclass
class GreedyFedSelector(SelectorBase):
    """Ours (Alg. 1): RR initialisation, then purely-greedy top-M cumulative SV."""
    averaging: str = "mean"     # "mean" | "exponential"
    alpha: float = 0.5          # exponential-averaging parameter

    name = "greedyfed"
    uses_shapley = True

    def sv_mode(self) -> str:
        return self.averaging

    def sv_alpha(self) -> float:
        return self.alpha

    def select(self, state, key, ctx):
        if state.round < self._rr_rounds():
            return self._rr_select(state), state
        sv = np.asarray(state.valuation.sv)
        return np.argsort(-sv, kind="stable")[: self.m], state


@dataclasses.dataclass
class GreedyFedDropoutSelector(GreedyFedSelector):
    """Beyond-paper (the paper's own Section VI future work): after the RR
    phase the server *feeds Shapley values back* and clients in the bottom
    `drop_frac` of cumulative SV drop out of the protocol entirely — they
    are never polled again, cutting standing communication/coordination
    overhead with (empirically, see benchmarks) no accuracy cost, since
    greedy selection would not have picked them anyway.

    The active set lives in the fixed-shape `state.active` bool mask
    (frozen at the first post-RR selection); `dropped_fraction(state)`
    reports the communication saving.
    """
    drop_frac: float = 0.5

    name = "greedyfed_dropout"

    def _n_keep(self) -> int:
        return max(self.m, int(round((1.0 - self.drop_frac)
                                     * self.n_clients)))

    def select(self, state, key, ctx):
        if state.round < self._rr_rounds():
            return self._rr_select(state), state
        if not state.frozen:
            sv = np.asarray(state.valuation.sv)
            rank = np.argsort(-sv, kind="stable")
            active = np.zeros(self.n_clients, bool)
            active[rank[: self._n_keep()]] = True
            state = state._replace(active=active, frozen=True)
        sv = np.where(state.active, np.asarray(state.valuation.sv), -np.inf)
        return np.argsort(-sv, kind="stable")[: self.m], state

    def dropped_fraction(self, state) -> float:
        if not state.frozen:
            return 0.0
        return 1.0 - int(state.active.sum()) / self.n_clients


SELECTORS = {
    "fedavg": RandomSelector,
    "fedprox": RandomSelector,       # prox term lives in the client update
    "power_of_choice": PowerOfChoiceSelector,
    "s_fedavg": SFedAvgSelector,
    "ucb": UCBSelector,
    "greedyfed": GreedyFedSelector,
    "greedyfed_dropout": GreedyFedDropoutSelector,  # beyond-paper (Sec. VI)
}


def make_selector(name: str, n_clients: int, m: int, seed: int = 0, **kw) -> SelectorBase:
    try:
        cls = SELECTORS[name]
    except KeyError:
        raise ValueError(f"unknown selector {name!r}; "
                         f"options: {sorted(SELECTORS)}") from None
    return cls(n_clients=n_clients, m=m, seed=seed, **kw)


def selector_spec(sel: SelectorBase) -> SelectorSpec:
    """The device twin's static config for a host selector instance."""
    d0 = getattr(sel, "d0", None)
    return SelectorSpec(
        name=sel.name,
        n_clients=sel.n_clients,
        m=sel.m,
        sv_mode=sel.sv_mode(),
        sv_alpha=sel.sv_alpha(),
        decay=getattr(sel, "decay", 0.9),
        # resolve the host's None-means-N default here so an explicit
        # d0=0 (clamps to m every round) survives the round trip
        d0=int(d0) if d0 is not None else sel.n_clients,
        c=getattr(sel, "c", 0.1),
        temperature=getattr(sel, "temperature", 1.0),
        drop_frac=getattr(sel, "drop_frac", 0.5),
    )

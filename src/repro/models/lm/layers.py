"""Shared layer primitives: norms, RoPE, FFN, embeddings.

Pure functions over param dicts; initialisers take an explicit key.  All
matmul param layouts are chosen so the `model` mesh axis shards the widest
contraction-free dimension (heads / d_ff / experts / vocab) — see
launch/sharding.py for the partition rules keyed on these param names.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _init(key, shape, scale):
    return (jax.random.normal(key, shape, jnp.float32) * scale)


def dense_init(key, d_in, shape_out):
    """Weight (d_in, *shape_out) with fan-in scaling."""
    return _init(key, (d_in, *shape_out), (1.0 / d_in) ** 0.5)


# ---------------------------------------------------------------- norms ----
def norm_init(d):
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(p, x, eps=1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"]).astype(x.dtype)


def layernorm(p, x, eps=1e-6):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"]).astype(x.dtype)


def apply_norm(kind, p, x):
    return rmsnorm(p, x) if kind == "rms" else layernorm(p, x)


# ----------------------------------------------------------------- RoPE ----
def rope_frequencies(hd: int, frac: float, theta: float) -> jax.Array:
    """Inverse frequencies for the rotary fraction of the head dim."""
    rot = int(hd * frac) // 2 * 2
    return 1.0 / (theta ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot))


def apply_rope(x: jax.Array, positions: jax.Array, *, frac: float,
               theta: float) -> jax.Array:
    """x: (..., S, H, hd); positions: (S,) absolute token positions.

    Rotates the first `frac * hd` components (chatglm3 2D-RoPE == frac 0.5),
    passes the rest through unchanged.
    """
    hd = x.shape[-1]
    inv = rope_frequencies(hd, frac, theta)
    rot = inv.shape[0] * 2
    ang = positions[:, None].astype(jnp.float32) * inv[None, :]   # (S, rot/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    # broadcast over leading dims and the head axis: (..., S, 1, rot/2)
    shape = (1,) * (x.ndim - 3) + (positions.shape[0], 1, inv.shape[0])
    cos = cos.reshape(shape)
    sin = sin.reshape(shape)
    xr = x[..., :rot].astype(jnp.float32)
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    y1 = x1 * cos - x2 * sin
    y2 = x1 * sin + x2 * cos
    yr = jnp.stack([y1, y2], axis=-1).reshape(xr.shape).astype(x.dtype)
    return jnp.concatenate([yr, x[..., rot:]], axis=-1)


# ------------------------------------------------------------------ FFN ----
def ffn_init(key, d_model, d_ff, kind):
    k1, k2, k3 = jax.random.split(key, 3)
    if kind == "swiglu":
        return {"w_gate": dense_init(k1, d_model, (d_ff,)),
                "w_up": dense_init(k2, d_model, (d_ff,)),
                "w_down": dense_init(k3, d_ff, (d_model,))}
    return {"w_up": dense_init(k1, d_model, (d_ff,)),
            "w_down": dense_init(k2, d_ff, (d_model,))}


def ffn_apply(p, x, kind):
    if kind == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"].astype(x.dtype)) * (x @ p["w_up"].astype(x.dtype))
    else:
        h = jax.nn.gelu(x @ p["w_up"].astype(x.dtype))
    return h @ p["w_down"].astype(x.dtype)


# ----------------------------------------------------------- embeddings ----
def embed_init(key, vocab, d_model):
    return {"table": _init(key, (vocab, d_model), 0.02)}


def embed_apply(p, tokens, dtype):
    return p["table"].astype(dtype)[tokens]


def head_apply(p, x):
    """LM head: (B, S, D) @ (D, V) -> logits upcast to f32 for a stable loss.

    The dot runs in the activation dtype (bf16 on TPU) so the vocab-sharded
    psum of dx in the backward pass moves bf16, not f32 — §Perf iteration 1
    halved the stem collective term this way; the f32 upcast for logsumexp
    happens after the contraction.
    """
    logits = x @ p["w"].astype(x.dtype)
    return logits.astype(jnp.float32)


def head_init(key, d_model, vocab):
    return {"w": dense_init(key, d_model, (vocab,))}


def cross_entropy_tokens(logits: jax.Array, labels: jax.Array,
                         mask: jax.Array | None = None) -> jax.Array:
    """Mean CE over (B, S) tokens; logits (B, S, V) float32."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    per = logz - gold
    if mask is None:
        return jnp.mean(per)
    m = mask.astype(jnp.float32)
    return jnp.sum(per * m) / jnp.maximum(jnp.sum(m), 1.0)

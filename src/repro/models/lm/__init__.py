from repro.models.lm.config import ArchConfig, param_count, active_param_count
from repro.models.lm.model import (
    init_params, forward, loss_fn, train_step, make_train_step,
    init_cache, prefill_step, decode_step,
)

__all__ = [
    "ArchConfig", "param_count", "active_param_count",
    "init_params", "forward", "loss_fn", "train_step", "make_train_step",
    "init_cache", "prefill_step", "decode_step",
]

"""Mamba2 / SSD (state-space duality) layer [arXiv:2405.21060].

Chunked SSD forward: within-chunk attention-like block (C B^T ⊙ decay) plus
an inter-chunk recurrence over per-chunk states — O(S * Q) compute, O(1)
decode state.  Single B/C group (G=1), multi-head over d_inner/P heads.

TPU adaptation (DESIGN.md §3): chunk length Q is the MXU tile knob; all
decay math in float32; the inter-chunk recurrence is a lax.scan whose carry
(B, H, P, N) stays resident (maps to VMEM on TPU).

Decode: h' = exp(dt*A) h + dt * (B ⊗ x);  y = C·h' + D_skip * x   (O(1)).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.lm.layers import dense_init, rmsnorm


def ssm_init(key, cfg):
    """Projections are kept separate (z / x / BC / dt) so each output dim can
    be sharded cleanly over the `model` axis — a fused in_proj would put the
    z|xBC|dt split boundaries inside shards (launch/sharding.py)."""
    d, di, n, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    k1, k2, k3, k4, k5, k6 = jax.random.split(key, 6)
    return {
        "proj_z": dense_init(k1, d, (di,)),
        "proj_x": dense_init(k2, d, (di,)),
        "proj_bc": dense_init(k3, d, (2 * n,)),
        "proj_dt": dense_init(k4, d, (h,)),
        "conv_x": jax.random.normal(k5, (cfg.ssm_conv, di), jnp.float32)
        * (1.0 / cfg.ssm_conv) ** 0.5,
        "conv_bc": jax.random.normal(k6, (cfg.ssm_conv, 2 * n), jnp.float32)
        * (1.0 / cfg.ssm_conv) ** 0.5,
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h, dtype=jnp.float32)),
        "D_skip": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((h,), 0.01, jnp.float32))),
        "norm": {"scale": jnp.ones((di,), jnp.float32)},
        "out_proj": dense_init(k4, di, (d,)),
    }


def _project(p, x):
    """x (..., D) -> (z, x_raw, bc_raw, dt_raw) pre-conv projections."""
    z = x @ p["proj_z"].astype(x.dtype)
    xr = x @ p["proj_x"].astype(x.dtype)
    bc = x @ p["proj_bc"].astype(x.dtype)
    dt = x @ p["proj_dt"].astype(x.dtype)
    return z, xr, bc, dt


def _causal_conv(u, conv_w):
    """Depthwise causal conv via shift-stack (window = ssm_conv)."""
    k = conv_w.shape[0]
    pads = jnp.pad(u, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pads[:, i: i + u.shape[1]] * conv_w[i].astype(u.dtype)
              for i in range(k))
    return jax.nn.silu(out)


def _gates(p, cfg, dt_raw):
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["A_log"])  # (H,) negative
    return dt, a


def ssm_forward(p, cfg, x):
    """x (B, S, D) -> (B, S, D).  S must be a multiple of ssm_chunk."""
    B, S, _ = x.shape
    di, n, h, pd = (cfg.d_inner, cfg.ssm_state, cfg.ssm_heads,
                    cfg.ssm_head_dim)
    q = min(cfg.ssm_chunk, S)
    assert S % q == 0, f"seq {S} not divisible by ssm chunk {q}"
    nc = S // q

    z, x_raw, bc_raw, dt_raw = _project(p, x)
    xc_in = _causal_conv(x_raw, p["conv_x"])
    bc = _causal_conv(bc_raw, p["conv_bc"])
    x_in = xc_in.reshape(B, S, h, pd).astype(jnp.float32)
    b_mat = bc[..., :n].astype(jnp.float32)                  # (B,S,N) G=1
    c_mat = bc[..., n:].astype(jnp.float32)
    dt, a = _gates(p, cfg, dt_raw)                           # (B,S,H), (H,)

    # chunk
    xc = x_in.reshape(B, nc, q, h, pd)
    bc = b_mat.reshape(B, nc, q, n)
    cc = c_mat.reshape(B, nc, q, n)
    dtc = dt.reshape(B, nc, q, h)
    da = dtc * a                                             # (B,nc,q,H) <= 0
    cum = jnp.cumsum(da, axis=2)                             # within-chunk

    # ---- intra-chunk (the "attention-like" quadratic-in-Q block) --------
    scores = jnp.einsum("bcin,bcjn->bcij", cc, bc)           # shared across H
    li = cum[:, :, :, None, :] - cum[:, :, None, :, :]       # (B,nc,i,j,H)
    tri = jnp.tril(jnp.ones((q, q), bool))
    decay = jnp.where(tri[None, None, :, :, None], jnp.exp(li), 0.0)
    y_intra = jnp.einsum("bcij,bcijh,bcjh,bcjhp->bcihp",
                         scores, decay, dtc, xc)

    # ---- inter-chunk recurrence ------------------------------------------
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)          # (B,nc,q,H)
    states = jnp.einsum("bcjh,bcjn,bcjhp->bchpn",
                        decay_to_end * dtc, bc, xc)          # per-chunk state
    chunk_decay = jnp.exp(cum[:, :, -1, :])                  # (B,nc,H)

    def scan_body(carry, inp):
        st, dec = inp                                        # (B,H,P,N), (B,H)
        new = carry * dec[:, :, None, None] + st
        return new, carry                                    # emit state *before* chunk

    init = jnp.zeros((B, h, pd, n), jnp.float32)
    _, prev_states = jax.lax.scan(
        scan_body, init,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)            # (B,nc,H,P,N)

    y_inter = jnp.einsum("bcin,bchpn,bcih->bcihp",
                         cc, prev_states, jnp.exp(cum))
    y = (y_intra + y_inter).reshape(B, S, h, pd)
    y = y + p["D_skip"][None, None, :, None] * x_in
    y = y.reshape(B, S, di)

    # gated RMSNorm then output projection
    y = rmsnorm(p["norm"], y.astype(x.dtype)) * jax.nn.silu(z)
    return y @ p["out_proj"].astype(x.dtype)


# ------------------------------------------------------------- decode ------
def ssm_cache_init(cfg, batch, dtype):
    di, n = cfg.d_inner, cfg.ssm_state
    return {
        "state": jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_head_dim, n), jnp.float32),
        "conv_x": jnp.zeros((batch, cfg.ssm_conv, di), dtype),
        "conv_bc": jnp.zeros((batch, cfg.ssm_conv, 2 * n), dtype),
    }


def ssm_decode_step(p, cfg, x, cache):
    """x (B, D) one token -> (y (B, D), new cache)."""
    di, n, h, pd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    z, x_new, bc_new, dt_raw = _project(p, x)

    conv_x = jnp.concatenate([cache["conv_x"][:, 1:], x_new[:, None]], axis=1)
    conv_bc = jnp.concatenate([cache["conv_bc"][:, 1:], bc_new[:, None]], axis=1)
    xc = jax.nn.silu(jnp.einsum("bkc,kc->bc", conv_x.astype(jnp.float32),
                                p["conv_x"]))
    bc = jax.nn.silu(jnp.einsum("bkc,kc->bc", conv_bc.astype(jnp.float32),
                                p["conv_bc"]))
    x_in = xc.reshape(-1, h, pd)
    b_mat = bc[:, :n]
    c_mat = bc[:, n:]
    dt, a = _gates(p, cfg, dt_raw)                           # (B,H), (H,)

    da = jnp.exp(dt * a)                                     # (B,H)
    state = cache["state"] * da[:, :, None, None] + jnp.einsum(
        "bh,bhp,bn->bhpn", dt, x_in, b_mat)
    y = jnp.einsum("bhpn,bn->bhp", state, c_mat)
    y = y + p["D_skip"][None, :, None] * x_in
    y = y.reshape(-1, di)

    y = rmsnorm(p["norm"], y.astype(x.dtype)) * jax.nn.silu(z)
    y = y @ p["out_proj"].astype(x.dtype)
    return y, {"state": state, "conv_x": conv_x, "conv_bc": conv_bc}


# --------------------------------------------------- reference (oracle) ----
def ssm_forward_ref(p, cfg, x):
    """Sequential O(S) recurrence — oracle for the chunked path."""
    B, S, _ = x.shape
    cache = ssm_cache_init(cfg, B, x.dtype)
    ys = []
    for t in range(S):
        y, cache = ssm_decode_step(p, cfg, x[:, t], cache)
        ys.append(y)
    return jnp.stack(ys, axis=1)

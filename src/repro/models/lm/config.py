"""Unified architecture config covering all six assigned families.

One frozen dataclass describes dense / MoE / SSM / hybrid / VLM / audio
backbones; family-specific fields are zero/empty when unused.  Configs for
the ten assigned architectures live in `repro.configs.<id>` and cite their
source papers.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int                # query heads (0 for attention-free SSM)
    n_kv_heads: int
    d_ff: int                   # dense FFN width (per-expert width for MoE)
    vocab: int
    head_dim: int = 0           # 0 => d_model // n_heads

    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    moe_groups: int = 1         # dispatch groups; launcher sets == data shards

    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0          # N
    ssm_head_dim: int = 64      # P
    ssm_expand: int = 2
    ssm_chunk: int = 256
    ssm_conv: int = 4

    # --- attention details ---
    rope_theta: float = 1e4
    rope_frac: float = 1.0      # chatglm "RoPE 2d": rotary on half the head dim
    window: int = 0             # sliding-window size (0 = full attention)
    ffn_kind: str = "swiglu"    # swiglu | gelu
    norm_kind: str = "rms"      # rms | layer

    # --- modality frontends (STUB: precomputed embeddings, see DESIGN.md) ---
    frontend: str = "none"      # none | vision | audio
    n_frontend_tokens: int = 0  # vision patches / audio frames

    # --- encoder-decoder (whisper) ---
    encoder_layers: int = 0     # 0 => decoder-only

    # --- numerics / distribution ---
    dtype: str = "bfloat16"
    param_dtype: str = "float32"   # "bfloat16" halves FSDP gathers + grad ARs
    parallelism: str = "tp"        # "tp": model axis shards weights;
                                   # "dp": model axis joins the batch axes
                                   # (right for small / non-divisible-head archs)
    attn_remat: bool = False       # checkpoint each flash KV block (backward
                                   # recomputes per block: peak mem / n_blocks)
    # sharding-constraint hooks: set by the launcher (empty => no-op, so
    # CPU smoke tests never touch mesh state)
    mesh_batch_axes: tuple = ()   # e.g. ("data",) or ("pod", "data")
    mesh_batch_sizes: tuple = ()  # matching axis sizes, for divisibility checks
    mesh_model_axis: str = ""     # e.g. "model"
    mesh_model_size: int = 0
    fsdp: bool = False          # shard params over the data axis too (>=10B)
    remat: bool = True          # activation checkpointing per layer
    scan_layers: bool = True    # False => python-unrolled layers (used by the
                                # roofline assembler: XLA HloCostAnalysis
                                # counts a while body once, not L times)
    optimizer: str = "adamw"    # adamw | sgd (paper's client optimizer)
    attn_chunk: int = 512       # flash kv-block size
    attn_impl: str = "auto"     # auto | dense | flash

    # source citation (paper table / model card)
    source: str = ""

    @property
    def hd(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def has_attn(self) -> bool:
        return self.n_heads > 0

    @property
    def has_ssm(self) -> bool:
        return self.ssm_state > 0

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def subquadratic(self) -> bool:
        """Can this arch run long_500k decode? (SSM state or bounded window.)"""
        if self.has_attn:
            return self.window > 0   # sliding-window: O(W) cache
        return self.has_ssm          # attention-free SSM: O(1) state

    def reduced(self, *, n_layers: int = 2, d_model: int | None = None,
                max_experts: int = 4) -> "ArchConfig":
        """Smoke-test variant: <=2 layers, d_model<=512, <=4 experts."""
        d = min(self.d_model, d_model or 256)
        # keep head structure but shrink
        n_heads = min(self.n_heads, 4) if self.n_heads else 0
        n_kv = min(self.n_kv_heads, max(n_heads // 2, 1)) if n_heads else 0
        upd = dict(
            name=self.name + "-reduced",
            n_layers=n_layers,
            encoder_layers=min(self.encoder_layers, n_layers) if self.encoder_layers else 0,
            d_model=d,
            n_heads=n_heads,
            n_kv_heads=n_kv,
            head_dim=d // max(n_heads, 1) if n_heads else 0,
            d_ff=min(self.d_ff, 2 * d) if self.d_ff else 0,
            vocab=min(self.vocab, 512),
            n_experts=min(self.n_experts, max_experts),
            top_k=min(self.top_k, 2),
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=32 if self.has_ssm else self.ssm_head_dim,
            ssm_chunk=64,
            window=min(self.window, 64) if self.window else 0,
            n_frontend_tokens=min(self.n_frontend_tokens, 8) if self.n_frontend_tokens else 0,
            dtype="float32",
            fsdp=False,
            remat=False,
            attn_impl="auto",
        )
        return dataclasses.replace(self, **upd)


def _attn_params(cfg: ArchConfig) -> int:
    if not cfg.has_attn:
        return 0
    d, hd = cfg.d_model, cfg.hd
    return d * cfg.n_heads * hd + 2 * d * cfg.n_kv_heads * hd + cfg.n_heads * hd * d


def _ffn_params(cfg: ArchConfig) -> int:
    if cfg.is_moe:
        per = (3 if cfg.ffn_kind == "swiglu" else 2) * cfg.d_model * cfg.d_ff
        return cfg.n_experts * per + cfg.d_model * cfg.n_experts  # + router
    if cfg.d_ff == 0:
        return 0
    return (3 if cfg.ffn_kind == "swiglu" else 2) * cfg.d_model * cfg.d_ff


def _ssm_params(cfg: ArchConfig) -> int:
    if not cfg.has_ssm:
        return 0
    d, di, n, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    d_in_proj = 2 * di + 2 * n + h       # z, x, B, C, dt (G=1 group)
    conv_dim = di + 2 * n
    return d * d_in_proj + cfg.ssm_conv * conv_dim + 3 * h + di + di * d


def _layer_params(cfg: ArchConfig) -> int:
    p = cfg.d_model  # norm1
    if cfg.d_ff > 0 or cfg.is_moe:
        p += cfg.d_model  # norm2 (pre-FFN)
    p += _attn_params(cfg) + _ffn_params(cfg) + _ssm_params(cfg)
    if cfg.family == "hybrid":
        p += 2 * cfg.d_model  # per-branch output norms (attn + ssm)
    return p


def param_count(cfg: ArchConfig) -> int:
    """Analytic total parameter count (matches init_params within ties)."""
    total = cfg.vocab * cfg.d_model            # embed
    total += cfg.d_model * cfg.vocab           # untied lm head
    total += cfg.d_model                       # final norm
    total += cfg.n_layers * _layer_params(cfg)
    if cfg.encoder_layers:                     # whisper encoder + cross-attn
        enc_layer = 2 * cfg.d_model + _attn_params(cfg) + _ffn_params(cfg)
        total += cfg.encoder_layers * enc_layer
        total += cfg.d_model                                        # enc final norm
        total += cfg.n_layers * (_attn_params(cfg) + cfg.d_model)  # cross-attn
    return total


def active_param_count(cfg: ArchConfig) -> int:
    """Params touched per token (MoE: top_k of n_experts)."""
    if not cfg.is_moe:
        return param_count(cfg)
    per_expert = (3 if cfg.ffn_kind == "swiglu" else 2) * cfg.d_model * cfg.d_ff
    inactive = cfg.n_layers * (cfg.n_experts - cfg.top_k) * per_expert
    return param_count(cfg) - inactive

"""Model assembly for all six families.

Layer parameters are *stacked* along a leading layer axis and the forward
pass is a `lax.scan` over layers (small HLO, fast multi-device compile;
roofline terms are assembled per-layer x trip-count, see launch/roofline).

Public API (all pure):
    init_params(cfg, key)                  -> params pytree
    forward(cfg, params, batch)            -> logits (B, S, V)
    loss_fn(cfg, params, batch)            -> scalar
    make_train_step(cfg)                   -> (params, opt, batch) -> ...
    init_cache(cfg, batch_size, cache_len) -> cache pytree
    prefill_step(cfg, params, batch)       -> (cache, last_logits)
    decode_step(cfg, params, cache, batch) -> (cache, logits)

Decode caches: KV tensors are (L, B, C, Kh, hd) ring buffers (C = window for
SWA archs — O(window) memory at 500k context); SSM caches are O(1) states.
"""
from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.lm.attention import attention, dense_attention
from repro.models.lm.config import ArchConfig
from repro.models.lm.layers import (
    apply_norm, apply_rope, cross_entropy_tokens, dense_init, embed_apply,
    embed_init, ffn_apply, ffn_init, head_apply, head_init, norm_init,
)
from repro.models.lm.moe import moe_apply, moe_init
from repro.models.lm.ssm import (
    ssm_cache_init, ssm_decode_step, ssm_forward, ssm_init,
)
from repro.optim import make_optimizer

PyTree = Any

MOE_AUX_WEIGHT = 0.01


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


def _constrain(cfg: ArchConfig, x, dims):
    """Sharding-constraint hook; no-op unless the launcher set mesh axes.

    dims entries: "batch" (shard over the batch axes), "model", or None.
    """
    if not cfg.mesh_batch_axes and not cfg.mesh_model_axis:
        return x
    from jax.sharding import PartitionSpec as P
    spec = []
    for i, d in enumerate(dims):
        if d == "batch":
            # keep only the leading batch axes that divide this dim
            axes, size = [], 1
            for a, s in zip(cfg.mesh_batch_axes, cfg.mesh_batch_sizes):
                if x.shape[i] % (size * s) == 0:
                    axes.append(a)
                    size *= s
            spec.append(tuple(axes) if len(axes) > 1 else (axes[0] if axes else None))
        elif d == "model" and cfg.mesh_model_size and x.shape[i] % cfg.mesh_model_size == 0:
            spec.append(cfg.mesh_model_axis)
        else:
            spec.append(None)
    return jax.lax.with_sharding_constraint(x, P(*spec))


# ======================================================== attention =========
def attn_init(key, cfg: ArchConfig, cross: bool = False):
    d, hq, kh, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": dense_init(k1, d, (hq, hd)),
        "wk": dense_init(k2, d, (kh, hd)),
        "wv": dense_init(k3, d, (kh, hd)),
        "wo": jax.random.normal(k4, (hq, hd, d), jnp.float32) * (1.0 / (hq * hd)) ** 0.5,
    }


def _qkv(p, cfg, x, kv_x=None, *, rope: bool, q_pos=None, kv_pos=None):
    kv_x = x if kv_x is None else kv_x
    q = jnp.einsum("bsd,dhe->bshe", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhe->bshe", kv_x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhe->bshe", kv_x, p["wv"].astype(x.dtype))
    if rope:
        q = apply_rope(q, q_pos, frac=cfg.rope_frac, theta=cfg.rope_theta)
        k = apply_rope(k, kv_pos, frac=cfg.rope_frac, theta=cfg.rope_theta)
    return q, k, v


def attn_apply_seq(p, cfg: ArchConfig, x, *, causal=True, rope=True,
                   kv_x=None, return_kv=False):
    """Full-sequence path (train / prefill / encoder)."""
    S = x.shape[1]
    t = (kv_x if kv_x is not None else x).shape[1]
    q_pos = jnp.arange(S)
    kv_pos = jnp.arange(t)
    q, k, v = _qkv(p, cfg, x, kv_x, rope=rope, q_pos=q_pos, kv_pos=kv_pos)
    o = attention(q, k, v, q_pos=q_pos, kv_pos=kv_pos, causal=causal,
                  window=cfg.window, impl=cfg.attn_impl,
                  kv_chunk=cfg.attn_chunk, remat=cfg.attn_remat)
    y = jnp.einsum("bshe,hed->bsd", o, p["wo"].astype(x.dtype))
    if return_kv:
        return y, (k, v)
    return y


def _ring_positions(pos, cache_len):
    """Absolute position stored in each ring slot; negative => unwritten."""
    s = jnp.arange(cache_len)
    return pos - ((pos - s) % cache_len)


def attn_apply_decode(p, cfg: ArchConfig, x, kv_cache, pos, *, rope=True):
    """One-token decode. x (B, 1, D); kv_cache {k,v}: (B, C, Kh, hd)."""
    cache_len = kv_cache["k"].shape[1]
    q_pos = pos[None] if pos.ndim == 0 else pos
    q, k_new, v_new = _qkv(p, cfg, x, rope=rope, q_pos=q_pos, kv_pos=q_pos)

    slot = pos % cache_len
    k = jax.lax.dynamic_update_slice_in_dim(kv_cache["k"], k_new.astype(kv_cache["k"].dtype), slot, 1)
    v = jax.lax.dynamic_update_slice_in_dim(kv_cache["v"], v_new.astype(kv_cache["v"].dtype), slot, 1)

    kv_pos = _ring_positions(pos, cache_len)
    kv_valid = kv_pos >= 0
    o = dense_attention(q, k, v, q_pos=q_pos, kv_pos=kv_pos, causal=True,
                        window=cfg.window, kv_valid=kv_valid)
    y = jnp.einsum("bshe,hed->bsd", o, p["wo"].astype(x.dtype))
    return y, {"k": k, "v": v}


def attn_apply_cross_decode(p, cfg, x, cross_kv):
    """Decoder cross-attention against a fixed encoder cache (no causality)."""
    k, v = cross_kv["k"], cross_kv["v"]
    t = k.shape[1]
    q = jnp.einsum("bsd,dhe->bshe", x, p["wq"].astype(x.dtype))
    kv_pos = jnp.arange(t)
    o = dense_attention(q, k, v, q_pos=jnp.zeros((1,), jnp.int32),
                        kv_pos=kv_pos, causal=False, window=0)
    return jnp.einsum("bshe,hed->bsd", o, p["wo"].astype(x.dtype))


# ====================================================== layer blocks ========
def layer_init(key, cfg: ArchConfig):
    keys = jax.random.split(key, 8)
    p = {"norm1": norm_init(cfg.d_model)}
    if cfg.has_attn:
        p["attn"] = attn_init(keys[0], cfg)
    if cfg.has_ssm:
        p["ssm"] = ssm_init(keys[1], cfg)
    if cfg.family == "hybrid":
        p["attn_out_norm"] = norm_init(cfg.d_model)
        p["ssm_out_norm"] = norm_init(cfg.d_model)
    if cfg.is_moe:
        p["norm2"] = norm_init(cfg.d_model)
        p["moe"] = moe_init(keys[2], cfg)
    elif cfg.d_ff > 0:
        p["norm2"] = norm_init(cfg.d_model)
        p["ffn"] = ffn_init(keys[3], cfg.d_model, cfg.d_ff, cfg.ffn_kind)
    if cfg.encoder_layers:  # decoder layer of an enc-dec model
        p["cross_norm"] = norm_init(cfg.d_model)
        p["cross_attn"] = attn_init(keys[4], cfg, cross=True)
    return p


def _mix_sublayer(p, cfg: ArchConfig, x):
    """Token-mixing sublayer on the *normed* input (full-sequence path)."""
    h = apply_norm(cfg.norm_kind, p["norm1"], x)
    if cfg.family == "hybrid":
        a = attn_apply_seq(p["attn"], cfg, h)
        s = ssm_forward(p["ssm"], cfg, h)
        a = apply_norm(cfg.norm_kind, p["attn_out_norm"], a)
        s = apply_norm(cfg.norm_kind, p["ssm_out_norm"], s)
        return 0.5 * (a + s)
    if cfg.has_ssm:
        return ssm_forward(p["ssm"], cfg, h)
    return attn_apply_seq(p["attn"], cfg, h)


def _ffn_sublayer(p, cfg: ArchConfig, x):
    if cfg.is_moe:
        h = apply_norm(cfg.norm_kind, p["norm2"], x)
        y, aux = moe_apply(p["moe"], cfg, h, n_groups=cfg.moe_groups,
                           constrain=partial(_constrain, cfg))
        return y, aux
    if cfg.d_ff > 0:
        h = apply_norm(cfg.norm_kind, p["norm2"], x)
        return ffn_apply(p["ffn"], h, cfg.ffn_kind), jnp.zeros((), jnp.float32)
    return jnp.zeros_like(x), jnp.zeros((), jnp.float32)


def decoder_layer(p, cfg: ArchConfig, x, cross_x=None):
    x = x + _mix_sublayer(p, cfg, x)
    if cfg.encoder_layers and cross_x is not None:
        h = apply_norm(cfg.norm_kind, p["cross_norm"], x)
        x = x + attn_apply_seq(p["cross_attn"], cfg, h, kv_x=cross_x,
                               causal=False, rope=False)
    y, aux = _ffn_sublayer(p, cfg, x)
    return x + y, aux


def encoder_layer(p, cfg: ArchConfig, x):
    h = apply_norm(cfg.norm_kind, p["norm1"], x)
    x = x + attn_apply_seq(p["attn"], cfg, h, causal=False, rope=False)
    y, aux = _ffn_sublayer(p, cfg, x)
    return x + y, aux


# ===================================================== init / forward =======
def _sinusoid(n, d, dtype):
    pos = jnp.arange(n, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    ang = pos / (10000.0 ** (dim / d))
    pe = jnp.zeros((n, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(ang)).at[:, 1::2].set(jnp.cos(ang))
    return pe.astype(dtype)


def init_params(cfg: ArchConfig, key: jax.Array) -> PyTree:
    params = _init_params_f32(cfg, key)
    pdt = jnp.dtype(cfg.param_dtype)
    if pdt != jnp.float32:
        params = jax.tree.map(lambda x: x.astype(pdt), params)
    return params


def _init_params_f32(cfg: ArchConfig, key: jax.Array) -> PyTree:
    k_emb, k_layers, k_head, k_enc = jax.random.split(key, 4)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    params = {
        "embed": embed_init(k_emb, cfg.vocab, cfg.d_model),
        "layers": jax.vmap(lambda k: layer_init(k, cfg))(layer_keys),
        "final_norm": norm_init(cfg.d_model),
        "head": head_init(k_head, cfg.d_model, cfg.vocab),
    }
    if cfg.encoder_layers:
        enc_cfg = cfg  # same width; encoder layers have no cross-attn
        enc_keys = jax.random.split(k_enc, cfg.encoder_layers)

        def enc_layer_init(k):
            keys = jax.random.split(k, 4)
            return {
                "norm1": norm_init(cfg.d_model),
                "attn": attn_init(keys[0], enc_cfg),
                "norm2": norm_init(cfg.d_model),
                "ffn": ffn_init(keys[1], cfg.d_model, cfg.d_ff, cfg.ffn_kind),
            }

        params["enc_layers"] = jax.vmap(enc_layer_init)(enc_keys)
        params["enc_norm"] = norm_init(cfg.d_model)
    return params


def _maybe_scan(cfg, body, init, xs):
    """lax.scan, or a python-unrolled equivalent when cfg.scan_layers=False."""
    if cfg.scan_layers:
        return jax.lax.scan(body, init, xs)
    n = jax.tree.leaves(xs)[0].shape[0]
    carry, ys = init, []
    for i in range(n):
        carry, y = body(carry, jax.tree.map(lambda t: t[i], xs))
        ys.append(y)
    ys = jax.tree.map(lambda *a: jnp.stack(a, 0), *ys)
    return carry, ys


def _scan_layers(cfg, layers, x, layer_fn):
    """lax.scan over stacked layer params, with optional per-layer remat."""
    fn = layer_fn
    if cfg.remat:
        fn = jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)

    def body(h, lp):
        h2, aux = fn(lp, h)
        h2 = _constrain(cfg, h2, ("batch", None, None))
        return h2, aux

    x = _constrain(cfg, x, ("batch", None, None))
    if not cfg.scan_layers:
        n = jax.tree.leaves(layers)[0].shape[0]
        aux_total = jnp.zeros((), jnp.float32)
        for i in range(n):
            lp = jax.tree.map(lambda t: t[i], layers)
            x, aux = body(x, lp)
            aux_total = aux_total + aux
        return x, aux_total
    x, auxs = jax.lax.scan(body, x, layers)
    return x, jnp.sum(auxs)


def encode(cfg: ArchConfig, params: PyTree, frames: jax.Array) -> jax.Array:
    """Whisper encoder over stub conv-frontend frames (B, F, D)."""
    dt = _dtype(cfg)
    x = frames.astype(dt) + _sinusoid(frames.shape[1], cfg.d_model, dt)[None]
    x, _ = _scan_layers(cfg, params["enc_layers"], x,
                        lambda lp, h: encoder_layer(lp, cfg, h))
    return apply_norm(cfg.norm_kind, params["enc_norm"], x)


def forward(cfg: ArchConfig, params: PyTree, batch: dict) -> tuple[jax.Array, jax.Array]:
    """Full-sequence forward. Returns (logits (B,S,V) fp32, moe aux loss)."""
    dt = _dtype(cfg)
    tokens = batch["tokens"]
    x = embed_apply(params["embed"], tokens, dt)

    if cfg.frontend == "vision":
        # stub ViT frontend: precomputed patch embeddings replace the first
        # n_frontend_tokens positions (image-prefix interleave)
        patches = batch["patches"].astype(dt)
        npatch = patches.shape[1]
        x = jnp.concatenate([patches, x[:, npatch:]], axis=1)

    cross = None
    if cfg.encoder_layers:
        cross = encode(cfg, params, batch["frames"])
        x = x + _sinusoid(x.shape[1], cfg.d_model, dt)[None]

    x, aux = _scan_layers(cfg, params["layers"], x,
                          lambda lp, h: decoder_layer(lp, cfg, h, cross))
    x = apply_norm(cfg.norm_kind, params["final_norm"], x)
    logits = head_apply(params["head"], x)
    return logits, aux


def loss_fn(cfg: ArchConfig, params: PyTree, batch: dict) -> jax.Array:
    logits, aux = forward(cfg, params, batch)
    labels = batch["tokens"][:, 1:]
    lg = logits[:, :-1]
    mask = jnp.ones(labels.shape, bool)
    if cfg.frontend == "vision":
        # only text positions contribute to the LM loss
        mask = jnp.arange(labels.shape[1])[None, :] >= cfg.n_frontend_tokens
    loss = cross_entropy_tokens(lg, labels, mask)
    return loss + MOE_AUX_WEIGHT * aux


def make_train_step(cfg: ArchConfig):
    opt_init, opt_step = make_optimizer(
        cfg.optimizer, lr=0.01 if cfg.optimizer == "sgd" else 3e-4,
        momentum=0.5)

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(partial(loss_fn, cfg))(params, batch)
        new_params, new_opt = opt_step(grads, opt_state, params)
        return new_params, new_opt, {"loss": loss}

    return opt_init, train_step


def train_step(cfg: ArchConfig, params, opt_state, batch):
    _, step = make_train_step(cfg)
    return step(params, opt_state, batch)


# ========================================================= serving ==========
def cache_len_for(cfg: ArchConfig, seq_len: int) -> int:
    if cfg.has_attn and cfg.window > 0:
        return min(seq_len, cfg.window)
    return seq_len


def init_cache(cfg: ArchConfig, batch: int, seq_len: int) -> PyTree:
    dt = _dtype(cfg)
    cache: dict = {"pos": jnp.zeros((), jnp.int32)}
    L = cfg.n_layers
    if cfg.has_attn:
        c = cache_len_for(cfg, seq_len)
        kv = lambda: jnp.zeros((L, batch, c, cfg.n_kv_heads, cfg.hd), dt)
        cache["k"], cache["v"] = kv(), kv()
    if cfg.has_ssm:
        per = ssm_cache_init(cfg, batch, dt)
        for k, v in per.items():
            cache[f"ssm_{k}"] = jnp.zeros((L,) + v.shape, v.dtype)
    if cfg.encoder_layers:
        cache["cross_k"] = jnp.zeros((L, batch, cfg.n_frontend_tokens,
                                      cfg.n_kv_heads, cfg.hd), dt)
        cache["cross_v"] = jnp.zeros_like(cache["cross_k"])
    return cache


def decode_step(cfg: ArchConfig, params: PyTree, cache: PyTree,
                batch: dict) -> tuple[PyTree, jax.Array]:
    """One decode step: batch {"token": (B,)} -> (cache', logits (B, V))."""
    dt = _dtype(cfg)
    pos = cache["pos"]
    x = embed_apply(params["embed"], batch["token"][:, None], dt)  # (B,1,D)

    # assemble per-layer cache slices for the scan
    carry_keys = [k for k in ("k", "v", "ssm_state", "ssm_conv_x",
                              "ssm_conv_bc", "cross_k", "cross_v") if k in cache]

    def body(h, inp):
        lp = inp["params"]
        new = {}
        y = apply_norm(cfg.norm_kind, lp["norm1"], h)
        ssm_cache_in = ({"state": inp["ssm_state"], "conv_x": inp["ssm_conv_x"],
                         "conv_bc": inp["ssm_conv_bc"]} if cfg.has_ssm else None)
        if cfg.family == "hybrid":
            a, kv = attn_apply_decode(lp["attn"], cfg, y, {"k": inp["k"], "v": inp["v"]}, pos)
            s, st = ssm_decode_step(lp["ssm"], cfg, y[:, 0], ssm_cache_in)
            a = apply_norm(cfg.norm_kind, lp["attn_out_norm"], a)
            s = apply_norm(cfg.norm_kind, lp["ssm_out_norm"], s[:, None])
            mix = 0.5 * (a + s)
            new.update(k=kv["k"], v=kv["v"], ssm_state=st["state"],
                       ssm_conv_x=st["conv_x"], ssm_conv_bc=st["conv_bc"])
        elif cfg.has_ssm:
            s, st = ssm_decode_step(lp["ssm"], cfg, y[:, 0], ssm_cache_in)
            mix = s[:, None]
            new.update(ssm_state=st["state"], ssm_conv_x=st["conv_x"],
                       ssm_conv_bc=st["conv_bc"])
        else:
            a, kv = attn_apply_decode(lp["attn"], cfg, y, {"k": inp["k"], "v": inp["v"]}, pos)
            mix = a
            new.update(k=kv["k"], v=kv["v"])
        h = h + mix
        if cfg.encoder_layers:
            hc = apply_norm(cfg.norm_kind, lp["cross_norm"], h)
            h = h + attn_apply_cross_decode(lp["cross_attn"], cfg, hc,
                                            {"k": inp["cross_k"], "v": inp["cross_v"]})
            new.update(cross_k=inp["cross_k"], cross_v=inp["cross_v"])
        y2, _ = _ffn_sublayer(lp, cfg, h)
        return h + y2, new

    xs = {"params": params["layers"]}
    for ck in carry_keys:
        xs[ck] = cache[ck]
    h, new_cols = _maybe_scan(cfg, body, x, xs)

    h = apply_norm(cfg.norm_kind, params["final_norm"], h)
    logits = head_apply(params["head"], h)[:, 0]

    new_cache = dict(cache)
    new_cache["pos"] = pos + 1
    for ck in carry_keys:
        new_cache[ck] = new_cols[ck]
    return new_cache, logits


def prefill_step(cfg: ArchConfig, params: PyTree, batch: dict,
                 cache_len: int | None = None) -> tuple[PyTree, jax.Array]:
    """Run the full prompt, build the decode cache, return last-token logits.

    For simplicity and lowering-robustness the cache is built by a full
    forward that returns per-layer K/V (attention archs) / final SSM states.
    """
    dt = _dtype(cfg)
    tokens = batch["tokens"]
    B, S = tokens.shape
    cache_len = cache_len or S
    c = cache_len_for(cfg, cache_len)

    x = embed_apply(params["embed"], tokens, dt)
    if cfg.frontend == "vision":
        patches = batch["patches"].astype(dt)
        x = jnp.concatenate([patches, x[:, patches.shape[1]:]], axis=1)
    cross = None
    if cfg.encoder_layers:
        cross = encode(cfg, params, batch["frames"])
        x = x + _sinusoid(S, cfg.d_model, dt)[None]

    cache = init_cache(cfg, B, cache_len)
    kv_rows, ssm_rows = [], []

    def layer_with_kv(lp, h):
        """decoder layer that also emits this layer's cache entries."""
        out = {}
        y = apply_norm(cfg.norm_kind, lp["norm1"], h)
        if cfg.has_attn:
            a, (k, v) = attn_apply_seq(lp["attn"], cfg, y, return_kv=True)
            if S >= c:
                # ring layout: keep the last `c` positions (aligned because
                # the launch shapes guarantee S % c == 0 for SWA caches)
                kk, vv = k[:, -c:], v[:, -c:]
            else:
                # room for decode: future slots stay zero; the ring-position
                # validity mask hides them until written
                pad = ((0, 0), (0, c - S), (0, 0), (0, 0))
                kk, vv = jnp.pad(k, pad), jnp.pad(v, pad)
            out["k"] = kk.astype(dt)
            out["v"] = vv.astype(dt)
        if cfg.family == "hybrid":
            s = ssm_forward(lp["ssm"], cfg, y)
            a = apply_norm(cfg.norm_kind, lp["attn_out_norm"], a)
            s2 = apply_norm(cfg.norm_kind, lp["ssm_out_norm"], s)
            mix = 0.5 * (a + s2)
        elif cfg.has_ssm:
            mix = ssm_forward(lp["ssm"], cfg, y)
        else:
            mix = a
        if cfg.has_ssm:
            # closed-form final state from the cumulative-decay sums (same
            # math as the chunked SSD inter-chunk states, single chunk)
            st, conv_x, conv_bc = _ssm_final_state(lp["ssm"], cfg, y)
            out["ssm_state"] = st
            out["ssm_conv_x"] = conv_x
            out["ssm_conv_bc"] = conv_bc
        h = h + mix
        if cfg.encoder_layers and cross is not None:
            hc = apply_norm(cfg.norm_kind, lp["cross_norm"], h)
            h = h + attn_apply_seq(lp["cross_attn"], cfg, hc, kv_x=cross,
                                   causal=False, rope=False)
            kx = jnp.einsum("bsd,dhe->bshe", cross, lp["cross_attn"]["wk"].astype(dt))
            vx = jnp.einsum("bsd,dhe->bshe", cross, lp["cross_attn"]["wv"].astype(dt))
            out["cross_k"], out["cross_v"] = kx.astype(dt), vx.astype(dt)
        y2, _ = _ffn_sublayer(lp, cfg, h)
        return h + y2, out

    h, cols = _maybe_scan(cfg, lambda hh, lp: layer_with_kv(lp, hh), x,
                          params["layers"])

    for k in cols:
        cache[k] = cols[k]
    # ring alignment: with a full-size cache, slot i == position i; with a
    # window cache the last c tokens land at slots (S-c..S-1) % c — for the
    # dry-run shapes S % c == 0, so the identity layout is already correct.
    cache["pos"] = jnp.asarray(S, jnp.int32)
    h = apply_norm(cfg.norm_kind, params["final_norm"], h[:, -1:])
    logits = head_apply(params["head"], h)[:, 0]
    return cache, logits


def _ssm_final_state(p, cfg, x):
    """Final (state, conv windows) after consuming x (B,S,D) — for prefill."""
    from repro.models.lm.ssm import _causal_conv, _gates, _project
    B, S, _ = x.shape
    di, n, h, pd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    z, x_raw, bc_raw, dt_raw = _project(p, x)
    xc = _causal_conv(x_raw, p["conv_x"])
    bc = _causal_conv(bc_raw, p["conv_bc"])
    x_in = xc.reshape(B, S, h, pd).astype(jnp.float32)
    b_mat = bc[..., :n].astype(jnp.float32)
    dt, a = _gates(p, cfg, dt_raw)
    da = dt * a                                  # (B,S,H)
    cum = jnp.cumsum(da, axis=1)
    decay_to_end = jnp.exp(cum[:, -1:, :] - cum)  # (B,S,H)
    state = jnp.einsum("bsh,bsn,bshp->bhpn", decay_to_end * dt, b_mat, x_in)
    pad = cfg.ssm_conv - 1
    conv_x = jnp.pad(x_raw, ((0, 0), (pad, 0), (0, 0)))[:, -cfg.ssm_conv:]
    conv_bc = jnp.pad(bc_raw, ((0, 0), (pad, 0), (0, 0)))[:, -cfg.ssm_conv:]
    return state, conv_x.astype(x.dtype), conv_bc.astype(x.dtype)

"""Mixture-of-Experts FFN with gather-based capacity dispatch.

Routing: softmax router, top-k experts per token, per-expert capacity
C = ceil(tokens_per_group * top_k * capacity_factor / n_experts); overflow
tokens are dropped (standard Switch/GShard semantics).

Dispatch is *gather-based*, not the dense (T,E,C)x(T,D) einsum: we build an
(E, C) token-index table via a cumsum-over-assignments rank and gather
expert inputs directly.  This keeps dispatch FLOPs ~0 (bytes only) so the
compiled roofline reflects real expert compute — the dense-dispatch einsum
would dominate HLO_FLOPs by ~50x at kimi-k2 scale (DESIGN.md §3).

Tokens are processed in `moe_groups` independent groups; the launcher sets
groups == data-axis shards so dispatch tables are built from local tokens
only and expert parallelism (experts sharded over the `model` axis) needs a
single partial-sum reduction on the combine, no all-to-all of raw tokens.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.lm.layers import dense_init


def moe_init(key, cfg):
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    k1, k2, k3, k4 = jax.random.split(key, 4)
    params = {
        "router": dense_init(k1, d, (e,)),
        "w_up": jax.random.normal(k3, (e, d, f), jnp.float32) * (1.0 / d) ** 0.5,
        "w_down": jax.random.normal(k4, (e, f, d), jnp.float32) * (1.0 / f) ** 0.5,
    }
    if cfg.ffn_kind == "swiglu":
        params["w_gate"] = jax.random.normal(k2, (e, d, f), jnp.float32) * (1.0 / d) ** 0.5
    return params


def _capacity(cfg, tokens_per_group: int) -> int:
    c = int(tokens_per_group * cfg.top_k * cfg.capacity_factor / cfg.n_experts)
    return max(4, (c + 3) // 4 * 4)


def moe_apply(p, cfg, x, *, n_groups: int = 1, constrain=None):
    """x (B, S, D) -> (B, S, D), plus aux load-balance loss.

    `constrain(tensor, dims)` is an optional sharding-constraint hook
    (dims entries: "batch" | "model" | None) supplied by the launcher so
    dispatch tables stay local per data shard and expert tensors stay
    expert-sharded over the model axis.
    """
    cst = constrain or (lambda t, dims: t)
    B, S, D = x.shape
    T = B * S
    assert T % n_groups == 0, (T, n_groups)
    tg = T // n_groups
    cap = _capacity(cfg, tg)
    xg = cst(x.reshape(n_groups, tg, D), ("batch", None, None))

    def route(xt):                                         # (Tg, D) per group
        logits = (xt @ p["router"].astype(xt.dtype)).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)            # (Tg, E)
        top_w, top_idx = jax.lax.top_k(probs, cfg.top_k)   # (Tg, k)
        top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

        # rank of each assignment within its expert (token-major priority)
        flat_e = top_idx.reshape(-1)                       # (Tg*k,)
        onehot = jax.nn.one_hot(flat_e, cfg.n_experts, dtype=jnp.float32)
        pos = (jnp.cumsum(onehot, axis=0) * onehot).sum(-1)  # 1-indexed rank
        keep = pos <= cap

        token_ids = jnp.repeat(jnp.arange(tg), cfg.top_k)
        slot = jnp.where(keep, pos - 1, cap).astype(jnp.int32)

        # (E, C+1) tables; the +1 column swallows dropped assignments
        table = jnp.zeros((cfg.n_experts, cap + 1), jnp.int32).at[
            flat_e, slot].set(token_ids)[:, :cap]
        valid = jnp.zeros((cfg.n_experts, cap + 1), jnp.float32).at[
            flat_e, slot].set(1.0)[:, :cap]
        wtab = jnp.zeros((cfg.n_experts, cap + 1), jnp.float32).at[
            flat_e, slot].set(top_w.reshape(-1))[:, :cap]

        # GShard load-balance aux: mean fraction * mean prob per expert
        aux = cfg.n_experts * jnp.sum(jnp.mean(onehot, axis=0)
                                      * jnp.mean(probs, axis=0))
        return table, valid, wtab, aux

    table, valid, wtab, aux = jax.vmap(route)(xg)          # (G,E,C) tables

    # local gather per group (replicated over model), then slice to experts
    expert_in = jax.vmap(lambda xt, t: xt[t])(xg, table)
    expert_in = expert_in * valid[..., None].astype(x.dtype)
    expert_in = cst(expert_in, ("batch", "model", None, None))  # (G,E,C,D)

    if cfg.ffn_kind == "swiglu":
        h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", expert_in,
                                   p["w_gate"].astype(x.dtype)))
        h = h * jnp.einsum("gecd,edf->gecf", expert_in, p["w_up"].astype(x.dtype))
    else:
        h = jax.nn.gelu(jnp.einsum("gecd,edf->gecf", expert_in,
                                   p["w_up"].astype(x.dtype)))
    out = jnp.einsum("gecf,efd->gecd", h, p["w_down"].astype(x.dtype))
    contrib = out * (wtab * valid)[..., None].astype(x.dtype)  # (G,E,C,D)

    # combine: per-group scatter of expert-sharded partials -> psum(model)
    y = jax.vmap(lambda c, t: jnp.zeros((tg, D), x.dtype)
                 .at[t.reshape(-1)].add(c.reshape(-1, D)))(contrib, table)
    y = cst(y, ("batch", None, None))
    return y.reshape(B, S, D), jnp.mean(aux)


def moe_apply_ref(p, cfg, x):
    """Oracle: every expert on every token, no capacity (top-k weighting)."""
    B, S, D = x.shape
    xt = x.reshape(-1, D)
    logits = (xt @ p["router"].astype(xt.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_idx = jax.lax.top_k(probs, cfg.top_k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)
    gate = jnp.zeros_like(probs).at[
        jnp.arange(xt.shape[0])[:, None], top_idx].set(top_w)  # (T, E)

    if cfg.ffn_kind == "swiglu":
        h = jax.nn.silu(jnp.einsum("td,edf->tef", xt, p["w_gate"].astype(xt.dtype)))
        h = h * jnp.einsum("td,edf->tef", xt, p["w_up"].astype(xt.dtype))
    else:
        h = jax.nn.gelu(jnp.einsum("td,edf->tef", xt, p["w_up"].astype(xt.dtype)))
    out = jnp.einsum("tef,efd->ted", h, p["w_down"].astype(xt.dtype))
    y = jnp.einsum("ted,te->td", out, gate.astype(xt.dtype))
    return y.reshape(B, S, D)

"""GQA attention: dense reference, chunked-flash (pure JAX), decode w/ cache.

Never materialises the full (S, T) score matrix for long sequences: the
flash path is a lax.scan over KV blocks carrying the running (max, denom,
acc) per query — the same online-softmax recurrence as the Pallas kernel in
kernels/flash_attention.py (which is the TPU-target implementation; this
pure-JAX version is what the dry-run lowers, see DESIGN.md §3).

Sliding-window attention (SWA) is a banded mask; on the flash path fully
out-of-window KV blocks are skipped at runtime via lax.cond (true compute
skipping — the scan is not vmapped over the block axis).

Shapes: q (B, S, Hq, hd) with Hq = Kh * G (GQA group G); k/v (B, T, Kh, hd).
Internally q is regrouped to (B, S, Kh, G, hd) so the contraction never
repeats KV heads.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _mask(q_pos, kv_pos, *, causal: bool, window: int, kv_valid=None):
    """(..., S, T) boolean mask: True = attend."""
    m = jnp.ones(q_pos.shape + kv_pos.shape, bool)
    if causal:
        m &= kv_pos[None, :] <= q_pos[:, None]
    if window > 0:
        m &= kv_pos[None, :] > q_pos[:, None] - window
    if kv_valid is not None:
        m &= kv_valid[None, :]
    return m


def dense_attention(q, k, v, *, q_pos, kv_pos, causal=True, window=0,
                    kv_valid=None):
    """Reference / decode path. q (B,S,Hq,hd), k/v (B,T,Kh,hd)."""
    B, S, Hq, hd = q.shape
    T, Kh = k.shape[1], k.shape[2]
    G = Hq // Kh
    qg = q.reshape(B, S, Kh, G, hd)
    scale = hd ** -0.5
    s = jnp.einsum("bskgd,btkd->bkgst", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    mask = _mask(q_pos, kv_pos, causal=causal, window=window, kv_valid=kv_valid)
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgst,btkd->bskgd", p, v.astype(jnp.float32))
    return o.reshape(B, S, Hq, hd).astype(q.dtype)


def flash_attention(q, k, v, *, q_pos, causal=True, window=0, kv_chunk=512,
                    remat=False):
    """Online-softmax over KV blocks; memory O(S * kv_chunk) per head.

    Assumes T % kv_chunk == 0 (launch/input specs guarantee this).
    remat=True checkpoints each KV-block step, so the backward pass
    recomputes per block instead of saving every block's (S, kv_chunk)
    probability tensor — peak activation memory drops ~n_blocks-fold
    (§Perf hymba iteration).
    """
    B, S, Hq, hd = q.shape
    T, Kh = k.shape[1], k.shape[2]
    G = Hq // Kh
    n_blocks = T // kv_chunk
    qg = q.reshape(B, S, Kh, G, hd).astype(jnp.float32)
    scale = hd ** -0.5

    def body(carry, blk):
        acc, m, l = carry
        start = blk * kv_chunk
        kb = jax.lax.dynamic_slice_in_dim(k, start, kv_chunk, 1).astype(jnp.float32)
        vb = jax.lax.dynamic_slice_in_dim(v, start, kv_chunk, 1).astype(jnp.float32)
        kv_pos = start + jnp.arange(kv_chunk)
        mask = _mask(q_pos, kv_pos, causal=causal, window=window)  # (S, kc)

        def compute(_):
            s = jnp.einsum("bskgd,btkd->bkgst", qg, kb) * scale
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum("bkgst,btkd->bkgsd", p, vb)
            return acc_new, m_new, l_new

        # runtime block skipping: causal blocks entirely in the future, or
        # SWA blocks entirely behind the window
        any_valid = jnp.any(mask)
        acc, m, l = jax.lax.cond(any_valid, compute, lambda _: (acc, m, l),
                                 operand=None)
        return (acc, m, l), None

    acc0 = jnp.zeros((B, Kh, G, S, hd), jnp.float32)
    m0 = jnp.full((B, Kh, G, S), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Kh, G, S), jnp.float32)
    body_fn = jax.checkpoint(body) if remat else body
    (acc, m, l), _ = jax.lax.scan(body_fn, (acc0, m0, l0),
                                  jnp.arange(n_blocks))
    o = acc / jnp.maximum(l, 1e-30)[..., None]
    # (B,Kh,G,S,hd) -> (B,S,Hq,hd)
    o = jnp.moveaxis(o, 3, 1).reshape(B, S, Hq, hd)
    return o.astype(q.dtype)


def attention(q, k, v, *, q_pos, kv_pos=None, causal=True, window=0,
              impl="auto", kv_chunk=512, kv_valid=None, remat=False):
    """Dispatch: dense for short/decode, flash for long train/prefill."""
    T = k.shape[1]
    if impl == "auto":
        impl = "flash" if (q.shape[1] > 1024 and T % kv_chunk == 0) else "dense"
    if impl == "flash":
        return flash_attention(q, k, v, q_pos=q_pos, causal=causal,
                               window=window, kv_chunk=kv_chunk, remat=remat)
    if kv_pos is None:
        kv_pos = jnp.arange(T)
    return dense_attention(q, k, v, q_pos=q_pos, kv_pos=kv_pos, causal=causal,
                           window=window, kv_valid=kv_valid)

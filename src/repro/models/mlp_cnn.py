"""The paper's task models: MLP (MNIST/FMNIST) and CNN (CIFAR10).

Pure-functional: params are pytrees, `apply(params, x) -> logits`,
`loss(params, x, y) -> scalar CE`.  No flax dependency (offline container).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple, Sequence

import jax
import jax.numpy as jnp

PyTree = Any


class ClassifierModel(NamedTuple):
    name: str
    init: Callable[[jax.Array], PyTree]
    apply: Callable[[PyTree, jax.Array], jax.Array]

    def loss(self, params: PyTree, x: jax.Array, y: jax.Array) -> jax.Array:
        logits = self.apply(params, x)
        return cross_entropy(logits, y)

    def accuracy(self, params: PyTree, x: jax.Array, y: jax.Array) -> jax.Array:
        logits = self.apply(params, x)
        return jnp.mean((jnp.argmax(logits, -1) == y).astype(jnp.float32))


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(logz - gold)


def _dense_init(key, d_in, d_out, scale=None):
    scale = scale if scale is not None else (2.0 / d_in) ** 0.5
    wk, _ = jax.random.split(key)
    return {"w": jax.random.normal(wk, (d_in, d_out), jnp.float32) * scale,
            "b": jnp.zeros((d_out,), jnp.float32)}


def make_mlp(input_dim: int = 784, hidden: Sequence[int] = (200, 100),
             n_classes: int = 10) -> ClassifierModel:
    dims = [input_dim, *hidden, n_classes]

    def init(key):
        keys = jax.random.split(key, len(dims) - 1)
        return {f"layer{i}": _dense_init(keys[i], dims[i], dims[i + 1])
                for i in range(len(dims) - 1)}

    def apply(params, x):
        h = x.reshape((x.shape[0], -1))
        n_layers = len(dims) - 1
        for i in range(n_layers):
            p = params[f"layer{i}"]
            h = h @ p["w"] + p["b"]
            if i < n_layers - 1:
                h = jax.nn.relu(h)
        return h

    return ClassifierModel("mlp", init, apply)


def make_cnn(input_shape=(32, 32, 3), n_classes: int = 10,
             channels: Sequence[int] = (32, 64), dense: int = 128) -> ClassifierModel:
    h, w, c_in = input_shape

    def init(key):
        keys = jax.random.split(key, len(channels) + 2)
        params = {}
        c_prev = c_in
        for i, c in enumerate(channels):
            fan_in = 3 * 3 * c_prev
            params[f"conv{i}"] = {
                "w": jax.random.normal(keys[i], (3, 3, c_prev, c), jnp.float32)
                * (2.0 / fan_in) ** 0.5,
                "b": jnp.zeros((c,), jnp.float32),
            }
            c_prev = c
        hh, ww = h // (2 ** len(channels)), w // (2 ** len(channels))
        flat = hh * ww * c_prev
        params["dense0"] = _dense_init(keys[-2], flat, dense)
        params["head"] = _dense_init(keys[-1], dense, n_classes)
        return params

    def apply(params, x):
        hcur = x
        for i in range(len(channels)):
            p = params[f"conv{i}"]
            hcur = jax.lax.conv_general_dilated(
                hcur, p["w"], window_strides=(1, 1), padding="SAME",
                dimension_numbers=("NHWC", "HWIO", "NHWC"))
            hcur = jax.nn.relu(hcur + p["b"])
            hcur = jax.lax.reduce_window(
                hcur, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID")
        hcur = hcur.reshape((hcur.shape[0], -1))
        hcur = jax.nn.relu(hcur @ params["dense0"]["w"] + params["dense0"]["b"])
        return hcur @ params["head"]["w"] + params["head"]["b"]

    return ClassifierModel("cnn", init, apply)


@functools.lru_cache(maxsize=None)
def make_classifier(dataset: str) -> ClassifierModel:
    """Memoized: the same dataset always yields the SAME (hashable) model
    object, so jit caches keyed on the model — notably the round engine's
    fused step — are shared across runs instead of re-tracing per run."""
    if dataset in ("mnist", "fmnist"):
        return make_mlp()
    if dataset == "cifar10":
        return make_cnn()
    raise ValueError(f"no classifier for dataset {dataset!r}")

from repro.models.mlp_cnn import ClassifierModel, make_mlp, make_cnn, make_classifier

__all__ = ["ClassifierModel", "make_mlp", "make_cnn", "make_classifier"]

from repro.federated.partition import dirichlet_partition, power_law_fractions
from repro.federated.client import ClientConfig, client_update, local_loss
from repro.federated.server import FLConfig, run_federated, FLResult

__all__ = [
    "dirichlet_partition", "power_law_fractions",
    "ClientConfig", "client_update", "local_loss",
    "FLConfig", "run_federated", "FLResult",
]

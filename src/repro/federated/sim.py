"""Client-parallel FL simulation: one round == one collective step.

torch-style FL simulators loop selected clients serially; here the M
selected clients' local updates run as a vmapped (and, under a mesh,
data-axis-sharded) computation — DESIGN.md §3 "client parallelism".  The
stacked updates feed GTG-Shapley directly (its subset averages contract
over the client axis, which GSPMD turns into small all-reduces).

`device_selected_round` extends the collective step upward through the
strategy layer: with a device-resident selector (repro.core.selection_jax)
the round's *selection* is part of the same trace, so select → gather →
train → aggregate is one program — the single-round building block of the
whole-run scan engine (DESIGN.md §11), exposed standalone for interactive
use and mesh lowering.

Works on 1 CPU device (plain vmap) and on a production mesh (client axis
sharded over "data"): tests/test_sharding.py lowers it on a debug mesh.
"""
from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.aggregation import normalized_weights, weighted_average
from repro.core.selection_jax import (
    DeviceSelectionContext, DeviceSelectorState, SelectorSpec, device_select,
    device_update,
)
from repro.engine.batch_client import batched_client_update, cohort_update
from repro.federated.client import ClientConfig
from repro.models.mlp_cnn import ClassifierModel

PyTree = Any


@partial(jax.jit, static_argnames=("model", "ccfg"))
def parallel_client_round(
    model: ClassifierModel,
    ccfg: ClientConfig,
    params: PyTree,          # replicated server model w^t
    xs: jax.Array,           # (M, cap, ...) selected clients' padded data
    ys: jax.Array,           # (M, cap)
    n_valid: jax.Array,      # (M,)
    epochs_k: jax.Array,     # (M,) straggler-adjusted local epochs
    sigma_k: jax.Array,      # (M,) privacy noise levels
    keys: jax.Array,         # (M,) rng keys
) -> tuple[PyTree, PyTree]:
    """Run all M ClientUpdates in parallel; return (stacked updates, w^{t+1}).

    The cohort vmap is the engine's (`repro.engine.batch_client`); the fused
    `round_step` extends it with codec + Shapley + aggregation in one trace.
    """
    stacked = batched_client_update(model, ccfg, params, xs, ys, n_valid,
                                    epochs_k, sigma_k, keys)
    new_params = weighted_average(
        stacked, normalized_weights(n_valid.astype(jnp.float32)))
    return stacked, new_params


@partial(jax.jit, static_argnames=("model", "ccfg", "spec"))
def device_selected_round(
    model: ClassifierModel,
    ccfg: ClientConfig,
    spec: SelectorSpec,
    params: PyTree,          # replicated server model w^t
    xs_all: jax.Array,       # (N, cap, ...) ALL clients' padded data
    ys_all: jax.Array,       # (N, cap)
    nv_all: jax.Array,       # (N,)
    sigma_all: jax.Array,    # (N,)
    epochs_all: jax.Array,   # (N,) this round's per-client epoch budgets
    state: DeviceSelectorState,
    ctx: DeviceSelectionContext,
    key: jax.Array,
) -> tuple[jax.Array, DeviceSelectorState, PyTree]:
    """Fused select → gather → train → aggregate: ONE jitted program.

    The strategy picks the cohort *inside* the trace (no host round-trip
    between selection and training), then the vmapped cohort update and
    ModelAverage run exactly as in `parallel_client_round`.  Returns
    (sel, selector state with bumped counts, w^{t+1}).  SV-driven
    strategies feed their valuation separately via `device_update` once
    the round's Shapley values exist (see round_engine.make_run_scan for
    the fully-fused variant).
    """
    sel_key, round_key = jax.random.split(key)
    sel, state = device_select(spec, state, sel_key, ctx)
    stacked, n_k_sel, _ = cohort_update(
        model, ccfg, params, xs_all, ys_all, nv_all, sigma_all, sel,
        jnp.take(epochs_all, sel), round_key)
    new_params = weighted_average(stacked, normalized_weights(n_k_sel))
    state = device_update(spec, state, sel)
    return sel, state, new_params

"""Client-parallel FL simulation: one round == one collective step.

torch-style FL simulators loop selected clients serially; here the M
selected clients' local updates run as a vmapped (and, under a mesh,
data-axis-sharded) computation — DESIGN.md §3 "client parallelism".  The
stacked updates feed GTG-Shapley directly (its subset averages contract
over the client axis, which GSPMD turns into small all-reduces).

Works on 1 CPU device (plain vmap) and on a production mesh (client axis
sharded over "data"): tests/test_sharding.py lowers it on a debug mesh.
"""
from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.aggregation import normalized_weights, weighted_average
from repro.engine.batch_client import batched_client_update
from repro.federated.client import ClientConfig
from repro.models.mlp_cnn import ClassifierModel

PyTree = Any


@partial(jax.jit, static_argnames=("model", "ccfg"))
def parallel_client_round(
    model: ClassifierModel,
    ccfg: ClientConfig,
    params: PyTree,          # replicated server model w^t
    xs: jax.Array,           # (M, cap, ...) selected clients' padded data
    ys: jax.Array,           # (M, cap)
    n_valid: jax.Array,      # (M,)
    epochs_k: jax.Array,     # (M,) straggler-adjusted local epochs
    sigma_k: jax.Array,      # (M,) privacy noise levels
    keys: jax.Array,         # (M,) rng keys
) -> tuple[PyTree, PyTree]:
    """Run all M ClientUpdates in parallel; return (stacked updates, w^{t+1}).

    The cohort vmap is the engine's (`repro.engine.batch_client`); the fused
    `round_step` extends it with codec + Shapley + aggregation in one trace.
    """
    stacked = batched_client_update(model, ccfg, params, xs, ys, n_valid,
                                    epochs_k, sigma_k, keys)
    new_params = weighted_average(
        stacked, normalized_weights(n_valid.astype(jnp.float32)))
    return stacked, new_params

"""The federated server loop — GreedyFed Alg. 1 plus all baselines.

One function, `run_federated`, drives T communication rounds:
  select clients -> ClientUpdate at each -> ModelAverage -> GTG-Shapley
  -> cumulative-SV update -> next round.
Strategy behaviour is fully encapsulated in a `SelectorSpec` + device
selector state (`repro.core.selection_jax` — the single runtime selector
implementation, DESIGN.md §13), so FedAvg / FedProx / Power-of-Choice /
S-FedAvg / UCB / GreedyFed all share this loop (the paper's experimental
protocol).

Round execution is pluggable (``cfg.engine``, DESIGN.md §6, §11):
  * "loop"    — the paper-faithful per-client Python loop (M dispatches per
                round); kept verbatim as the parity oracle;
  * "batched" — `repro.engine.RoundEngine`: the whole round (cohort gather,
                vmapped local training, upload codec, GTG-Shapley,
                ModelAverage) fused into ONE jitted dispatch;
  * "scan"    — `repro.engine.scan_engine`: the whole T-round RUN as one
                `lax.scan` dispatch, with selection and valuation living
                on-device (`repro.core.selection_jax`).

With ``cfg.schedule`` set, stragglers stop being randomly drawn: a virtual
clock derives each client's E_k from the round deadline
(`repro.engine.schedule`, DESIGN.md §9) and the run reports simulated
wall-clock time.  `run_federated_replicated` vmaps the fused round over a
seed axis so multi-seed benchmark tables amortise one compilation.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregation import normalized_weights, tree_stack, weighted_average
from repro.core.selection_jax import (
    DeviceSelectionContext, DeviceSelectorState, SelectorSpec,
    init_device_state, jitted_selector, make_selector_spec, poc_d_schedule,
)
from repro.core.shapley import gtg_shapley
from repro.data.synth import SynthDataset, make_dataset
from repro.engine.schedule import (
    ScheduleConfig, VirtualClock, deadline_epochs, eval_mask,
    make_client_clock, round_duration_s,
)
from repro.federated.client import ClientConfig, client_update, local_loss
from repro.federated.compression import compress_update
from repro.federated.partition import (
    client_cap, dirichlet_partition, padded_x_block, padded_y_block,
    power_law_fractions, valid_counts,
)
from repro.models.mlp_cnn import ClassifierModel, make_classifier

PyTree = Any


@dataclass(frozen=True)
class FLConfig:
    dataset: str = "mnist"
    n_clients: int = 50          # N
    m: int = 5                   # M: clients selected per round
    rounds: int = 50             # T: communication budget
    selector: str = "greedyfed"
    selector_kwargs: dict = field(default_factory=dict)
    client: ClientConfig = ClientConfig()
    # round-execution engine: "loop" (per-client dispatches, parity oracle),
    # "batched" (fused single-dispatch round), or "scan" (whole run as one
    # lax.scan dispatch with device-resident selection)
    engine: str = "loop"
    # heterogeneity knobs (paper Section IV)
    dirichlet_alpha: float = 1e-4
    straggler_frac: float = 0.0  # x
    privacy_sigma: float = 0.0   # sigma
    # first-class privacy-noise grid axis (related repo's `noise_level`,
    # ROADMAP scenario diversity): each client gets an EXTRA uniform
    # [0, noise_level) update-noise sigma, folded into its per-client
    # sigma on the host before the run so the on-device round body is
    # unchanged.  0.0 (default) draws nothing — rng-stream neutral.
    noise_level: float = 0.0
    # random-straggler E_k stream revision (DESIGN.md §12):
    #   1 (default) — all engines draw the whole (T, N) budget table up
    #     front (engine.schedule.straggler_epochs_table), so loop/batched/
    #     scan are STREAM-identical under straggler_frac > 0;
    #   0 — legacy: loop/batched lazily draw per selected straggler in
    #     selection order (the paper-faithful stream the seed shipped
    #     with); scan stays table-driven, distribution-identical only.
    straggler_rev: int = 1
    # virtual-clock timing model; when set, E_k is deadline-derived and
    # straggler_frac is ignored (DESIGN.md §9)
    schedule: Optional[ScheduleConfig] = None
    # GTG-Shapley
    shapley_eps: float = 1e-4
    shapley_max_iters: Optional[int] = None   # default 50*M
    # "streaming" (DESIGN.md §14 incremental prefix walk — the default
    # device SV path for every engine) | "batched" (§8 dense oracle) |
    # "serial" (Alg. 2, within-round truncation; degrades under the
    # scan/replica-vmap engines, where lax.cond runs both branches)
    shapley_impl: str = "streaming"
    # streaming SV: prefix models materialised + evaluated per step,
    # rounded up to whole M-model walks — the memory knob that lets GTG
    # run inside replica-sharded grids at paper scale (peak SV memory
    # O(max(sv_chunk, M) * D) instead of O(R*M*D)).  0 = auto (one walk
    # off-TPU, all R*M on TPU), < 0 forces the all-resident pass; every
    # chunking is bit-identical, so the knob never changes results.
    sv_chunk: int = 0
    sv_averaging: str = "mean"   # "mean" | "exponential"
    sv_alpha: float = 0.5
    # upload compression (paper Related-Work contrast; see
    # federated/compression.py): applied to the client->PS delta
    upload_codec: str = "identity"
    # fault injection + hardened execution (repro.faults, DESIGN.md §19):
    # `faults` (a repro.faults.FaultSpec) pre-draws a (T, N) fault-code
    # table in setup_run — NaN/Inf poison, sign-flip/scaled byzantine
    # updates, mid-round crash dropout — consumed identically by all
    # engines; `quarantine` enables the in-round screen that masks
    # non-finite / norm-outlier updates out of aggregation, SV walks, and
    # the byte ledger.  Quarantine-on over a clean run is bit-identical
    # to off.  All three are grid-static (one executable per setting).
    faults: Optional[Any] = None
    quarantine: bool = False
    quarantine_z: float = 8.0
    # bookkeeping
    eval_every: int = 5
    seed: int = 0
    n_train: int = 6000
    n_val: int = 500
    n_test: int = 1000
    # client-axis sharding (DESIGN.md §16, engine="scan" only): shard the
    # (N, cap, ...) client stacks + per-client selector state over this
    # many devices, making per-device client memory O(N / clients_shards).
    # Bit-identical to the dense run at any value; 1 = dense (default).
    clients_shards: int = 1


class FLResult(NamedTuple):
    config: FLConfig
    test_acc: list            # [(round, acc)]
    val_loss: list            # [(round, loss)]
    final_acc: float
    sv_final: np.ndarray      # (N,)
    selection_counts: np.ndarray
    selections: list          # [np.ndarray (M,)] per round
    shapley_evals: int        # total utility evaluations spent
    wall_time_s: float
    params: PyTree
    upload_bytes: int = 0     # total client->PS traffic over the run
    download_bytes: int = 0   # total PS->client traffic (model broadcasts)
    sim_time_s: float = 0.0   # virtual-clock seconds (0 without schedule)
    dispatches: int = 0       # host->device program launches issued
    # wall_time_s split (DESIGN.md §15): jit trace+lower+compile seconds
    # attributed via jax.monitoring vs everything else.  A warm executable
    # (cached round/scan programs) reports compile_time_s ~ 0, so the
    # headline timing no longer silently includes first-dispatch compiles.
    compile_time_s: float = 0.0
    execute_time_s: float = 0.0
    # total cohort rows masked by the fault/quarantine stage (§19); 0 on
    # fault-free runs and whenever hardening is off
    quarantined_total: int = 0


def _pad_clients(x, y, parts):
    cap = client_cap(parts)
    n = len(parts)
    return (jnp.asarray(padded_x_block(x, parts, cap, 0, n)),
            jnp.asarray(padded_y_block(y, parts, cap, 0, n)),
            jnp.asarray(valid_counts(parts, 0, n)))


def _shard_clients(x, y, parts, mesh):
    """Client-axis-sharded padded stacks, materialised lazily per shard.

    Each device's rows of the (N_pad, cap, ...) stacks are built from the
    partition indices via `jax.make_array_from_callback`, so the host
    never holds the dense O(N) stacks — only one shard block at a time
    (DESIGN.md §16).  Rows [n_clients, N_pad) are zero pad clients.
    """
    from repro.grid.shard import clients_padded
    from repro.launch.mesh import CLIENT_AXIS
    n_pad = clients_padded(len(parts), mesh.shape[CLIENT_AXIS])
    cap = client_cap(parts)

    def build(shape, dtype, block):
        spec = jax.sharding.PartitionSpec(
            CLIENT_AXIS, *([None] * (len(shape) - 1)))
        sharding = jax.sharding.NamedSharding(mesh, spec)

        def cb(index):
            lo = index[0].start or 0
            hi = shape[0] if index[0].stop is None else index[0].stop
            return block(lo, hi).astype(dtype)

        return jax.make_array_from_callback(shape, sharding, cb)

    xs = build((n_pad, cap) + x.shape[1:], np.float32,
               lambda lo, hi: padded_x_block(x, parts, cap, lo, hi))
    ys = build((n_pad, cap), np.int32,
               lambda lo, hi: padded_y_block(y, parts, cap, lo, hi))
    nv = build((n_pad,), np.int32,
               lambda lo, hi: valid_counts(parts, lo, hi))
    return xs, ys, nv


class RunSetup(NamedTuple):
    """Everything `run_federated` derives from an FLConfig before round 0.

    Shared with `engine.replicated` so the multi-seed path reproduces the
    exact same rng/key streams as a solo run at the same seed.
    """
    data: SynthDataset
    model: ClassifierModel
    rng: np.random.Generator
    key: jax.Array
    fractions: np.ndarray
    xs: jax.Array
    ys: jax.Array
    n_valid: jax.Array
    n_k_all: jax.Array
    straggler_ids: set
    sigma_k_all: np.ndarray
    params: PyTree
    sel_spec: SelectorSpec               # the run's selection strategy
    sel_state: DeviceSelectorState       # its initial device state
    x_val: jax.Array
    y_val: jax.Array
    x_test: jax.Array
    y_test: jax.Array
    model_bytes: int
    clock: Any                # engine.schedule.ClientClock | None
    # (T, N) pre-drawn random-straggler budgets (straggler_rev >= 1 only;
    # None under a schedule, without stragglers, or at straggler_rev=0)
    epochs_table: Any = None
    # (T, N) pre-drawn int32 fault-code table (cfg.faults only, §19)
    fault_table: Any = None


def setup_run(cfg: FLConfig, data: Optional[SynthDataset] = None,
              model: Optional[ClassifierModel] = None, *,
              client_mesh=None) -> RunSetup:
    """Partition data, assign heterogeneity, init model/selector state.

    Draw order on `rng`/`key` is frozen (parity across engines and with the
    seed history); anything new must draw strictly after the existing calls.
    `client_mesh` (a mesh with a CLIENT_AXIS, DESIGN.md §16) switches the
    padded stacks to lazily-materialised client-axis-sharded arrays; the
    rng/key streams and every derived value are unchanged (the stacks just
    gain zero pad rows that nothing reads).
    """
    rng = np.random.default_rng(cfg.seed)
    key = jax.random.key(cfg.seed)

    if data is None:
        data = make_dataset(cfg.dataset, n_train=cfg.n_train, n_val=cfg.n_val,
                            n_test=cfg.n_test, seed=cfg.seed)
    if model is None:
        model = make_classifier(cfg.dataset)

    # ---- partition data across clients (Dirichlet x power-law) ----------
    fractions = power_law_fractions(cfg.n_clients, rng)
    parts = dirichlet_partition(data.y_train, cfg.n_clients,
                                cfg.dirichlet_alpha, rng, fractions)
    if client_mesh is not None:
        xs, ys, n_valid = _shard_clients(data.x_train, data.y_train, parts,
                                         client_mesh)
    else:
        xs, ys, n_valid = _pad_clients(data.x_train, data.y_train, parts)
    n_k_all = n_valid.astype(jnp.float32)

    # ---- heterogeneity assignments --------------------------------------
    n_stragglers = int(round(cfg.straggler_frac * cfg.n_clients))
    straggler_ids = set(rng.choice(cfg.n_clients, n_stragglers,
                                   replace=False).tolist())
    noise_perm = rng.permutation(cfg.n_clients)  # sigma_k = rank * sigma / N
    sigma_k_all = np.zeros(cfg.n_clients, np.float32)
    for rank, k in enumerate(noise_perm):
        sigma_k_all[k] = rank * cfg.privacy_sigma / cfg.n_clients

    # ---- model / selector setup ------------------------------------------
    key, init_key = jax.random.split(key)
    params = model.init(init_key)
    # sv_averaging/sv_alpha reach GreedyFed-family selectors through the
    # spec (explicit selector_kwargs win) — never by mutating state after
    # construction.  selection_jax is the single runtime implementation
    # (DESIGN.md §13); neither call consumes the run's rng/key streams.
    sel_kwargs = dict(cfg.selector_kwargs)
    if cfg.selector in ("greedyfed", "greedyfed_dropout"):
        sel_kwargs.setdefault("averaging", cfg.sv_averaging)
        sel_kwargs.setdefault("alpha", cfg.sv_alpha)
    sel_spec = make_selector_spec(cfg.selector, cfg.n_clients, cfg.m,
                                  **sel_kwargs)
    sel_state = init_device_state(sel_spec, cfg.seed)

    model_bytes = sum(int(x.size) * x.dtype.itemsize
                      for x in jax.tree.leaves(params))

    # ---- virtual clock (draws AFTER all legacy consumption of rng) ------
    clock = None
    if cfg.schedule is not None:
        clock = make_client_clock(cfg.schedule, cfg.n_clients, model_bytes,
                                  rng, n_k=np.asarray(n_valid)[:cfg.n_clients])

    # ---- straggler_rev >= 1: pre-draw the (T, N) budget table -----------
    # Drawn at the exact stream position where the scan engine used to
    # draw it (first consumption of rng after setup), so rev=1 keeps the
    # scan engine's tables bitwise unchanged while making loop/batched
    # consume the SAME table — all three engines stream-identical.
    epochs_table = None
    if cfg.straggler_rev >= 1 and clock is None and straggler_ids:
        from repro.engine.schedule import straggler_epochs_table
        epochs_table = straggler_epochs_table(
            rng, cfg.rounds, cfg.n_clients, straggler_ids,
            cfg.client.epochs)

    # ---- noise_level: extra per-client update-noise sigma (gated) -------
    # Folded into sigma_k_all on the host so the device round body is
    # untouched; sqrt(sigma^2 + 0^2) is NOT bitwise sigma in f32, hence
    # the gate — noise_level=0.0 configs keep the exact legacy sigmas
    # AND an untouched rng stream.
    if cfg.noise_level > 0:
        extra = rng.uniform(0.0, cfg.noise_level, cfg.n_clients)
        sigma_k_all = np.sqrt(sigma_k_all.astype(np.float64) ** 2
                              + extra ** 2).astype(np.float32)

    # ---- faults: pre-draw the (T, N) fault-code table (gated, §19) ------
    # Same discipline as the straggler table: drawn strictly AFTER every
    # other consumer of `rng`, gated on cfg.faults, so fault-free configs
    # are rng-stream (and therefore bitwise) unchanged.
    fault_table = None
    if cfg.faults is not None:
        from repro.faults import draw_fault_table
        fault_table = draw_fault_table(cfg.faults, cfg.rounds,
                                       cfg.n_clients, rng)

    return RunSetup(
        data=data, model=model, rng=rng, key=key, fractions=fractions,
        xs=xs, ys=ys, n_valid=n_valid, n_k_all=n_k_all,
        straggler_ids=straggler_ids, sigma_k_all=sigma_k_all, params=params,
        sel_spec=sel_spec, sel_state=sel_state,
        x_val=jnp.asarray(data.x_val), y_val=jnp.asarray(data.y_val),
        x_test=jnp.asarray(data.x_test), y_test=jnp.asarray(data.y_test),
        model_bytes=model_bytes, clock=clock, epochs_table=epochs_table,
        fault_table=fault_table,
    )


def round_epochs(cfg: FLConfig, s: RunSetup, sel: np.ndarray,
                 t: int = 0) -> np.ndarray:
    """(M,) int32 local-epoch budget E_k for the selected cohort at round t.

    Deadline-derived when a schedule is set (DESIGN.md §9); otherwise a
    gather from the pre-drawn (T, N) straggler table (straggler_rev >= 1,
    stream-identical across all engines), falling back to the legacy
    per-selection draw from `s.rng` at straggler_rev=0.
    """
    e = cfg.client.epochs
    if s.clock is not None:
        return deadline_epochs(s.clock, cfg.schedule, sel, e)
    if s.epochs_table is not None:
        return s.epochs_table[t][np.asarray(sel)].astype(np.int32)
    out = np.full(len(sel), e, np.int32)
    for i, k_id in enumerate(sel):
        if int(k_id) in s.straggler_ids:
            out[i] = int(s.rng.integers(1, e + 1))
    return out


def _make_round_engine(cfg: FLConfig, s: RunSetup, needs_sv: bool,
                       max_iters: int):
    from repro.engine.round_engine import RoundEngine, RoundSpec
    spec = RoundSpec(needs_sv=needs_sv, shapley_impl=cfg.shapley_impl,
                     shapley_eps=cfg.shapley_eps, shapley_max_iters=max_iters,
                     sv_chunk=cfg.sv_chunk, upload_codec=cfg.upload_codec,
                     faults=cfg.faults, quarantine=cfg.quarantine,
                     quarantine_z=cfg.quarantine_z)
    return RoundEngine(s.model, cfg.client, spec, s.xs, s.ys, s.n_valid,
                       jnp.asarray(s.sigma_k_all), s.x_val, s.y_val)


def run_federated(cfg: FLConfig, data: Optional[SynthDataset] = None,
                  model: Optional[ClassifierModel] = None, *,
                  telemetry=None) -> FLResult:
    """Drive one federated run; `telemetry` (repro.telemetry.Telemetry)
    opts into the structured event stream of DESIGN.md §15 — the default
    None path adds zero dispatches and leaves every output bit-identical.
    """
    from repro.telemetry.trace import CompileTimer

    t_start = time.perf_counter()
    if cfg.engine not in ("loop", "batched", "scan"):
        raise ValueError(f"unknown engine {cfg.engine!r}; "
                         "options: 'loop', 'batched', 'scan'")
    from repro.engine.round_engine import SHAPLEY_IMPLS
    if cfg.shapley_impl not in SHAPLEY_IMPLS:
        raise ValueError(f"unknown shapley_impl {cfg.shapley_impl!r}; "
                         f"options: {SHAPLEY_IMPLS}")
    client_mesh = None
    if cfg.clients_shards > 1:
        if cfg.engine != "scan":
            raise ValueError("clients_shards > 1 requires engine='scan' "
                             "(the loop/batched engines are host-driven "
                             "and hold dense stacks by design)")
        from repro.launch.mesh import make_run_mesh
        client_mesh = make_run_mesh(1, cfg.clients_shards)
    ctimer = CompileTimer()
    with ctimer:
        s = setup_run(cfg, data, model, client_mesh=client_mesh)
    if telemetry is not None:
        from repro.telemetry.events import provenance
        telemetry.emit("run_start", run_id=telemetry.run_id, kind="solo",
                       engine=cfg.engine, selector=cfg.selector,
                       n_clients=cfg.n_clients, m=cfg.m,
                       rounds=cfg.rounds, seed=cfg.seed,
                       eval_every=cfg.eval_every, provenance=provenance())
    if cfg.engine == "scan":
        from repro.engine.scan_engine import run_federated_scan
        return run_federated_scan(cfg, s, t_start, telemetry=telemetry,
                                  ctimer=ctimer)
    model, params, key = s.model, s.params, s.key
    sel_spec, sstate = s.sel_spec, s.sel_state
    dev_select, dev_update = jitted_selector(sel_spec)

    def utility_fn(p):  # U(w) = -L(w; D_val)
        return -model.loss(p, s.x_val, s.y_val)

    batched_utility_fn = None
    if cfg.shapley_impl in ("batched", "streaming"):
        from repro.core.shapley_batched import make_batched_mlp_utility
        batched_utility_fn = make_batched_mlp_utility(model, s.x_val, s.y_val)

    needs_sv = sel_spec.uses_shapley
    max_iters = cfg.shapley_max_iters or 50 * cfg.m

    # §19 hardening for the host engines: the loop engine runs the exact
    # same jitted harden_cohort ops the fused/scan engines trace inline,
    # so all engines agree on what gets quarantined
    hardened = cfg.faults is not None or cfg.quarantine
    harden = None
    if hardened:
        from repro.faults import jitted_harden
        harden = jitted_harden(cfg.faults, cfg.quarantine, cfg.quarantine_z)

    def round_codes(sel, t):
        if s.fault_table is not None:
            return s.fault_table[t][np.asarray(sel)]
        return np.zeros(len(sel), np.int32)

    engine = None
    codec_bytes = s.model_bytes
    if cfg.engine == "batched":
        engine = _make_round_engine(cfg, s, needs_sv, max_iters)
        codec_bytes = engine.upload_nbytes_per_client(params)

    all_losses_fn = jax.jit(jax.vmap(
        lambda p, x, y, n: local_loss(model, p, x, y, n),
        in_axes=(None, 0, 0, 0)))

    eval_acc = jax.jit(model.accuracy)

    fractions = jnp.asarray(s.fractions)
    zero_losses = jnp.zeros((cfg.n_clients,), jnp.float32)
    d_sched = poc_d_schedule(sel_spec, cfg.rounds)
    emask = eval_mask(cfg.rounds, cfg.eval_every)

    test_acc, val_loss_hist, selections = [], [], []
    total_evals = 0
    upload_bytes = download_bytes = 0
    quarantined_total = 0
    dispatches = 0
    sv_rounds = trunc_rounds = 0   # telemetry-only truncation counters
    vclock = VirtualClock() if s.clock is not None else None

    # jit compiles during the rounds (first dispatch of each cached
    # program) are attributed to compile_time_s by the active timer
    with ctimer:
        for t in range(cfg.rounds):
            key, sel_key, round_key = jax.random.split(key, 3)

            losses = zero_losses
            if sel_spec.uses_local_losses:
                losses = all_losses_fn(params, s.xs, s.ys, s.n_valid)
                dispatches += 1

            ctx = DeviceSelectionContext(data_fractions=fractions,
                                         local_losses=losses,
                                         poc_d=jnp.asarray(d_sched[t]))
            sel_dev, sstate = dev_select(sstate, sel_key, ctx)
            sel = np.asarray(sel_dev, np.int64)
            selections.append(sel)
            epochs_k = round_epochs(cfg, s, sel, t)

            sv_round = None
            evals_round = 0
            trunc_round = None         # device bool; read only with telemetry
            round_upload = 0
            q_round = 0
            if engine is not None:
                # ---- fused round: ONE dispatch for train+codec+SV+average ----
                codes = round_codes(sel, t) if hardened else None
                out = engine.step(params, sel, epochs_k, round_key,
                                  fault_codes=codes)
                params = out.params
                if needs_sv:
                    sv_round = out.sv
                    evals_round = int(out.utility_evals)
                    total_evals += evals_round
                    trunc_round = out.sv_truncated
                if hardened:
                    # charge only survivors: quarantined uploads never
                    # reach the PS (crash) or are discarded at ingest
                    q_round = int(out.quarantined)
                    quarantined_total += q_round
                    round_upload = codec_bytes * int(np.asarray(out.ok).sum())
                else:
                    round_upload = codec_bytes * len(sel)
                upload_bytes += round_upload
                dispatches += 1
            else:
                # ---- legacy loop: ClientUpdate at each selected client -------
                ckeys = jax.random.split(round_key, len(sel) + 1)
                updates, nbytes_list = [], []
                for i, k_id in enumerate(sel):
                    upd = client_update(
                        model, cfg.client, params, s.xs[k_id], s.ys[k_id],
                        s.n_valid[k_id], jnp.asarray(int(epochs_k[i])),
                        jnp.asarray(s.sigma_k_all[k_id]), ckeys[i])
                    if cfg.upload_codec != "identity":
                        upd, nbytes = compress_update(cfg.upload_codec, upd,
                                                      params)
                    else:
                        nbytes = s.model_bytes
                    nbytes_list.append(nbytes)
                    updates.append(upd)
                dispatches += len(sel)

                stacked = tree_stack(updates)
                n_k_sel = s.n_k_all[jnp.asarray(sel)]

                # ---- §19 hardening: inject + screen + mask -------------------
                h = None
                n_k_sv = n_k_sel
                if hardened:
                    codes = jnp.asarray(round_codes(sel, t), jnp.int32)
                    h = harden(stacked, params, n_k_sel, codes)
                    stacked, n_k_sv = h.stacked, h.n_k_sv
                    ok_np = np.asarray(h.ok)
                    q_round = int(h.quarantined)
                    quarantined_total += q_round
                    round_upload = int(sum(
                        nb for nb, good in zip(nbytes_list, ok_np) if good))
                    dispatches += 1
                else:
                    round_upload = int(sum(nbytes_list))

                # ---- GTG-Shapley at the PS (Alg. 2 / device variants) --------
                if needs_sv:
                    if cfg.shapley_impl == "streaming":
                        from repro.core.shapley_batched import (
                            gtg_shapley_streaming,
                        )
                        sv_round, stats = gtg_shapley_streaming(
                            stacked, n_k_sv, params, utility_fn,
                            batched_utility_fn, ckeys[-1], eps=cfg.shapley_eps,
                            n_perms=max_iters, sv_chunk=cfg.sv_chunk)
                    elif cfg.shapley_impl == "batched":
                        from repro.core.shapley_batched import gtg_shapley_batched
                        sv_round, stats = gtg_shapley_batched(
                            stacked, n_k_sv, params, utility_fn,
                            batched_utility_fn, ckeys[-1], eps=cfg.shapley_eps,
                            n_perms=max_iters)
                    else:
                        sv_round, stats = gtg_shapley(
                            stacked, n_k_sv, params, utility_fn, ckeys[-1],
                            eps=cfg.shapley_eps, max_iters=max_iters)
                    evals_round = int(stats.utility_evals)
                    total_evals += evals_round
                    trunc_round = stats.truncated_round
                    dispatches += 1
                    if h is not None:
                        sv_round = jnp.where(h.ok, sv_round,
                                             jnp.zeros((), sv_round.dtype))

                # ---- ModelAverage (Alg. 1 line 9) ----------------------------
                if h is not None:
                    from repro.faults import masked_average
                    params = masked_average(stacked, h.n_k_agg, h.ok, params)
                else:
                    params = weighted_average(stacked,
                                              normalized_weights(n_k_sel))
                dispatches += 1
                upload_bytes += round_upload

            download_bytes += s.model_bytes * len(sel)  # w^t broadcast
            if vclock is not None:
                vclock.advance(round_duration_s(s.clock, cfg.schedule, sel,
                                                epochs_k))

            sstate = dev_update(sstate, sel_dev, sv_round)

            do_eval = bool(emask[t])
            if do_eval:
                acc = float(eval_acc(params, s.x_test, s.y_test))
                vl = float(-utility_fn(params))
                test_acc.append((t + 1, acc))
                val_loss_hist.append((t + 1, vl))
                dispatches += 2

            if telemetry is not None:
                truncated = bool(np.asarray(trunc_round)) \
                    if trunc_round is not None else False
                if needs_sv:
                    sv_rounds += 1
                    trunc_rounds += truncated
                fields = dict(round=t, selections=sel, epochs=epochs_k,
                              utility_evals=evals_round, sv_truncated=truncated,
                              upload_bytes=round_upload,
                              download_bytes=s.model_bytes * len(sel))
                if hardened:
                    fields["quarantined"] = q_round
                if sv_round is not None:
                    fields["sv"] = np.asarray(sv_round)
                telemetry.emit("round_metrics", **fields)
                if do_eval:
                    telemetry.emit("eval", round=t, test_acc=acc, val_loss=vl)

    counts = np.asarray(sstate.valuation.counts)
    wall = time.perf_counter() - t_start
    compile_s = ctimer.seconds
    final_acc = test_acc[-1][1] if test_acc else float("nan")
    if telemetry is not None:
        from repro.telemetry.metrics import run_end_payload
        telemetry.emit("compile", seconds=compile_s,
                       program=f"{cfg.engine}_round_programs")
        telemetry.emit("run_end", **run_end_payload(
            rounds=cfg.rounds, wall_time_s=wall, compile_time_s=compile_s,
            final_acc=final_acc, utility_evals=total_evals,
            upload_bytes=upload_bytes, download_bytes=download_bytes,
            sv_rounds=sv_rounds, truncated_rounds=trunc_rounds,
            dispatches=dispatches))
    return FLResult(
        config=cfg,
        test_acc=test_acc,
        val_loss=val_loss_hist,
        final_acc=final_acc,
        sv_final=np.asarray(sstate.valuation.sv),
        selection_counts=counts,
        selections=selections,
        shapley_evals=total_evals,
        wall_time_s=wall,
        params=params,
        upload_bytes=upload_bytes,
        download_bytes=download_bytes,
        sim_time_s=vclock.now_s if vclock is not None else 0.0,
        dispatches=dispatches,
        compile_time_s=compile_s,
        execute_time_s=max(wall - compile_s, 0.0),
        quarantined_total=quarantined_total,
    )


def run_federated_replicated(cfg: FLConfig, seeds,
                             data: Optional[SynthDataset] = None,
                             model: Optional[ClassifierModel] = None,
                             selectors=None, **grid_kwargs) -> list[FLResult]:
    """Run a replica batch with ONE fused program (repro.engine.replicated).

    With ``cfg.engine != "scan"`` and no `selectors`, this is the PR-1
    per-round vmap: the fused round step advances all seeds per dispatch
    (DESIGN.md §6).  With ``cfg.engine == "scan"`` (or a `selectors` list
    of registry names) the whole strategies × seeds table — selection and
    valuation included — runs through `repro.grid.run_grid`
    (DESIGN.md §12): one whole-run `lax.scan` dispatch per capability
    partition, optionally segmented/checkpointed and replica-sharded via
    keyword passthrough; results come back selector-major, seed-minor.
    """
    if cfg.engine == "scan" or selectors is not None:
        from repro.engine.replicated import run_replicated_scan
        return run_replicated_scan(cfg, seeds, selectors=selectors,
                                   data=data, model=model, **grid_kwargs)
    if grid_kwargs:
        raise ValueError("grid options (rounds_per_segment, "
                         "checkpoint_dir, ...) require engine='scan'")
    from repro.engine.replicated import run_replicated
    return run_replicated(cfg, seeds, data=data, model=model)


def run_centralized(cfg: FLConfig, data: Optional[SynthDataset] = None,
                    model: Optional[ClassifierModel] = None) -> FLResult:
    """Upper bound: the server trains on the pooled data, same step budget."""
    if data is None:
        data = make_dataset(cfg.dataset, n_train=cfg.n_train, n_val=cfg.n_val,
                            n_test=cfg.n_test, seed=cfg.seed)
    if model is None:
        model = make_classifier(cfg.dataset)
    key = jax.random.key(cfg.seed)
    key, init_key = jax.random.split(key)
    params = model.init(init_key)

    x = jnp.asarray(data.x_train)
    y = jnp.asarray(data.y_train)
    n = jnp.asarray(x.shape[0])
    t_start = time.time()
    test_acc = []
    eval_acc = jax.jit(model.accuracy)
    x_test, y_test = jnp.asarray(data.x_test), jnp.asarray(data.y_test)
    emask = eval_mask(cfg.rounds, cfg.eval_every)
    for t in range(cfg.rounds):
        key, k = jax.random.split(key)
        params = client_update(model, cfg.client, params, x, y, n,
                               jnp.asarray(cfg.client.epochs),
                               jnp.asarray(0.0), k)
        if emask[t]:
            test_acc.append((t + 1, float(eval_acc(params, x_test, y_test))))
    return FLResult(cfg, test_acc, [], test_acc[-1][1], np.zeros(cfg.n_clients),
                    np.zeros(cfg.n_clients, np.int32), [], 0,
                    time.time() - t_start, params)

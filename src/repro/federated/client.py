"""ClientUpdate (Alg. 1 line 7) — local training at a selected client.

Faithful to the paper's hyperparameters: E epochs x B minibatches per epoch
of SGD with momentum (eta=0.01, gamma=0.5), plus the three heterogeneity
mechanisms of Section IV:
  * FedProx: + mu/2 ||w - w^t||^2 proximal term in the local loss;
  * stragglers: client k only completes E_k ~ U{1..E} epochs;
  * privacy: N(0, sigma_k^2) noise added to the uploaded parameters.

All clients share one jitted step function: client datasets are padded to a
common capacity and minibatches are sampled by index into the valid prefix,
so XLA compiles the local update exactly once per (model, capacity).
"""
from __future__ import annotations

from functools import partial
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.aggregation import tree_sub, tree_sq_norm
from repro.models.mlp_cnn import ClassifierModel
from repro.optim.sgd import sgd_init, sgd_step

PyTree = Any


class ClientConfig(NamedTuple):
    epochs: int = 5            # E
    batches_per_epoch: int = 5 # B
    batch_size: int = 32
    lr: float = 0.01           # eta
    momentum: float = 0.5      # gamma
    prox_mu: float = 0.0       # FedProx mu (0 => FedAvg-style update)


@partial(jax.jit, static_argnames=("model", "cfg"))
def client_update(
    model: ClassifierModel,
    cfg: ClientConfig,
    params0: PyTree,
    x: jax.Array,           # (capacity, ...) padded client data
    y: jax.Array,           # (capacity,)
    n_valid: jax.Array,     # scalar int: true client dataset size
    epochs_k: jax.Array,    # scalar int: E_k (<= E for stragglers)
    sigma_k: jax.Array,     # scalar float: privacy noise std
    key: jax.Array,
) -> PyTree:
    """Run E_k * B SGD-momentum steps from params0; return noisy w_k^{t+1}."""
    total_steps = cfg.epochs * cfg.batches_per_epoch
    idx_key, noise_key = jax.random.split(key)
    # minibatch indices into the valid prefix, sampled with replacement
    idx = jax.random.randint(idx_key, (total_steps, cfg.batch_size), 0,
                             jnp.maximum(n_valid, 1))

    def local_loss_fn(p, xb, yb):
        loss = model.loss(p, xb, yb)
        if cfg.prox_mu > 0.0:
            loss = loss + 0.5 * cfg.prox_mu * tree_sq_norm(tree_sub(p, params0))
        return loss

    def step(i, carry):
        p, opt = carry
        xb, yb = x[idx[i]], y[idx[i]]
        grads = jax.grad(local_loss_fn)(p, xb, yb)
        p, opt = sgd_step(grads, opt, p, lr=cfg.lr, momentum=cfg.momentum)
        return (p, opt)

    # stragglers run only E_k of E epochs -> dynamic trip count
    n_steps = epochs_k * cfg.batches_per_epoch
    params, _ = jax.lax.fori_loop(0, n_steps, step, (params0, sgd_init(params0)))

    # privacy heterogeneity: obfuscate the uploaded model
    leaves, treedef = jax.tree.flatten(params)
    nkeys = jax.random.split(noise_key, len(leaves))
    noisy = [l + sigma_k * jax.random.normal(k, l.shape, l.dtype)
             for l, k in zip(leaves, nkeys)]
    return jax.tree.unflatten(treedef, noisy)


@partial(jax.jit, static_argnames=("model",))
def local_loss(model: ClassifierModel, params: PyTree, x: jax.Array,
               y: jax.Array, n_valid: jax.Array) -> jax.Array:
    """Masked mean loss of `params` on a client's (padded) data.

    Used by Power-of-Choice to rank candidate clients.
    """
    logits = model.apply(params, x)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, y[:, None], axis=-1)[:, 0]
    per = logz - gold
    mask = (jnp.arange(x.shape[0]) < n_valid).astype(jnp.float32)
    return jnp.sum(per * mask) / jnp.maximum(jnp.sum(mask), 1.0)

"""Client-upload compression codecs — the paper's Related-Work contrast.

GreedyFed reduces communication by selecting FEWER/BETTER clients; the
orthogonal line of work ([2],[3] in the paper) compresses each upload.
Implementing both lets benchmarks/comm_efficiency.py put the paper's claim
in bytes: rounds-to-accuracy x bytes-per-round for selection vs compression
vs both.

Codecs are pytree -> (payload, aux) encoders with exact byte accounting and
a decode that reconstructs the (lossy) update:

  * identity        — float32 baseline
  * quant8          — per-leaf symmetric int8 quantisation (4x)
  * topk            — magnitude top-k sparsification with int32 indices
                      ([3], Stich et al.), k as a fraction of each leaf
  * quant8_topk     — both (sparsify then quantise values)

All codecs are unbiased-ish lossy maps applied to the *delta* w_k - w^t
(deltas compress far better than raw weights), matching standard practice.

Two layers live here (DESIGN.md §18):

  * the original per-leaf codecs (`CODECS`, `codec_roundtrip`, ...) — the
    parity ORACLE: simple tree.map chains with `lax.top_k` + scatter;
  * a flat-vector layer (`FLAT_CODECS`, `flat_roundtrip`) that operates on
    the raveled delta with static per-leaf offsets/sizes — fixed payload
    shapes, fully jittable, and bitwise-equal to the oracle.  The fused
    `kernels/delta_codec` package implements the same row semantics in one
    HBM pass for the scan engine's cohort path.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

from repro.core.aggregation import tree_add, tree_sub

PyTree = Any

TOPK_FRAC = 0.1  # default sparsification fraction for the top-k codecs


class Encoded(NamedTuple):
    payload: PyTree      # codec-specific representation
    nbytes: int          # exact wire size of the payload


def _leaf_bytes(x: jax.Array) -> int:
    return int(x.size) * x.dtype.itemsize


# ----------------------------------------------------------- identity ------
def identity_encode(delta: PyTree) -> Encoded:
    return Encoded(delta, sum(_leaf_bytes(l) for l in jax.tree.leaves(delta)))


def identity_decode(enc: Encoded) -> PyTree:
    return enc.payload


# ------------------------------------------------------------- quant8 ------
def quant8_encode(delta: PyTree) -> Encoded:
    def enc(leaf):
        scale = jnp.maximum(jnp.max(jnp.abs(leaf)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(leaf / scale), -127, 127).astype(jnp.int8)
        return {"q": q, "scale": scale.astype(jnp.float32)}

    payload = jax.tree.map(enc, delta, is_leaf=lambda x: isinstance(x, jax.Array))
    nbytes = sum(int(l["q"].size) + 4
                 for l in jax.tree.leaves(payload,
                                          is_leaf=lambda x: isinstance(x, dict)
                                          and "q" in x))
    return Encoded(payload, nbytes)


def quant8_decode(enc: Encoded) -> PyTree:
    def dec(l):
        return l["q"].astype(jnp.float32) * l["scale"]

    return jax.tree.map(dec, enc.payload,
                        is_leaf=lambda x: isinstance(x, dict) and "q" in x)


# --------------------------------------------------------------- topk ------
def topk_encode(delta: PyTree, frac: float = 0.1) -> Encoded:
    def enc(leaf):
        flat = leaf.reshape(-1)
        k = max(1, int(flat.size * frac))
        vals, idx = jax.lax.top_k(jnp.abs(flat), k)
        return {"idx": idx.astype(jnp.int32), "val": flat[idx],
                "shape": leaf.shape}

    payload = jax.tree.map(enc, delta, is_leaf=lambda x: isinstance(x, jax.Array))
    nbytes = sum(int(l["idx"].size) * 4 + _leaf_bytes(l["val"])
                 for l in jax.tree.leaves(
                     payload, is_leaf=lambda x: isinstance(x, dict)
                     and "idx" in x))
    return Encoded(payload, nbytes)


def topk_decode(enc: Encoded) -> PyTree:
    def dec(l):
        flat = jnp.zeros(math.prod(l["shape"]), l["val"].dtype)
        return flat.at[l["idx"]].set(l["val"]).reshape(l["shape"])

    return jax.tree.map(dec, enc.payload,
                        is_leaf=lambda x: isinstance(x, dict) and "idx" in x)


# ----------------------------------------------------------- combined ------
def quant8_topk_encode(delta: PyTree, frac: float = 0.1) -> Encoded:
    sparse = topk_encode(delta, frac)

    def q(l):
        scale = jnp.maximum(jnp.max(jnp.abs(l["val"])), 1e-12) / 127.0
        return {**l, "val": jnp.clip(jnp.round(l["val"] / scale), -127, 127
                                     ).astype(jnp.int8), "scale": scale}

    payload = jax.tree.map(q, sparse.payload,
                           is_leaf=lambda x: isinstance(x, dict) and "idx" in x)
    nbytes = sum(int(l["idx"].size) * (4 + 1) + 4
                 for l in jax.tree.leaves(
                     payload, is_leaf=lambda x: isinstance(x, dict)
                     and "idx" in x))
    return Encoded(payload, nbytes)


def quant8_topk_decode(enc: Encoded) -> PyTree:
    def dec(l):
        vals = l["val"].astype(jnp.float32) * l["scale"]
        flat = jnp.zeros(math.prod(l["shape"]), jnp.float32)
        return flat.at[l["idx"]].set(vals).reshape(l["shape"])

    return jax.tree.map(dec, enc.payload,
                        is_leaf=lambda x: isinstance(x, dict) and "idx" in x)


CODECS = {
    "identity": (identity_encode, identity_decode),
    "quant8": (quant8_encode, quant8_decode),
    "topk": (partial(topk_encode, frac=TOPK_FRAC), topk_decode),
    "quant8_topk": (partial(quant8_topk_encode, frac=TOPK_FRAC),
                    quant8_topk_decode),
}


def compress_update(codec: str, w_new: PyTree, w_ref: PyTree
                    ) -> tuple[PyTree, int]:
    """Encode w_new relative to w_ref; return (reconstructed w_new, bytes).

    The server applies the lossy reconstruction — exactly what it would
    receive over the wire.
    """
    enc_fn, dec_fn = CODECS[codec]
    enc = enc_fn(tree_sub(w_new, w_ref))
    return tree_add(w_ref, dec_fn(enc)), enc.nbytes


def codec_roundtrip(codec: str, w_new: PyTree, w_ref: PyTree) -> PyTree:
    """Pure-array encode->decode (no byte count): safe to trace under
    jit/vmap, e.g. per-cohort inside the fused round engine."""
    enc_fn, dec_fn = CODECS[codec]
    return tree_add(w_ref, dec_fn(enc_fn(tree_sub(w_new, w_ref))))


def codec_nbytes(codec: str, tree: PyTree) -> int:
    """Wire size of one encoded update for a model of `tree`'s shapes.

    Every codec's byte count depends on leaf shapes only, so it is a
    per-run constant — computed once here instead of per client per round.
    """
    enc_fn, _ = CODECS[codec]
    return enc_fn(jax.tree.map(jnp.zeros_like, tree)).nbytes


# ===================================================== flat-vector layer ====
# Same codecs, re-expressed over the raveled delta vector with STATIC leaf
# sizes/offsets.  Payload shapes are fixed (no data-dependent scatter), so
# every op jits/vmaps cleanly; per-leaf segments are static slices.  Each
# flat codec is bitwise-equal to its per-leaf oracle above (pinned in
# tests/test_compression.py).

def flat_sizes(tree: PyTree) -> tuple[int, ...]:
    """Static per-leaf element counts, in `jax.tree.leaves` order."""
    return tuple(math.prod(l.shape) for l in jax.tree.leaves(tree))


def _offsets(sizes: tuple[int, ...]) -> tuple[int, ...]:
    out, off = [], 0
    for n in sizes:
        out.append(off)
        off += n
    return tuple(out)


def leaf_topk_k(n: int, frac: float = TOPK_FRAC) -> int:
    """Per-leaf k for the sparse codecs — identical to the oracle's rule."""
    return max(1, int(n * frac))


def topk_keep_mask(seg: jax.Array, k: int) -> jax.Array:
    """Exact keep mask for magnitude top-k with `lax.top_k` tie semantics.

    The mask is scattered from `lax.top_k`'s own index set (ties break
    lowest-index-first), so reconstruction is bitwise-equal to the
    oracle's dense scatter by construction.  The scatter has static
    shapes — only the payload layout must be data-independent, not the
    ops — and consuming top_k's indices whole keeps XLA's fast partial
    TopK; deriving a threshold by slicing out the k-th value would
    defeat the TopK rewrite and fall back to a full O(d log d) sort.
    """
    _, idx = jax.lax.top_k(jnp.abs(seg), k)
    keep = jnp.zeros(seg.shape, bool)
    return jnp.put_along_axis(keep, idx, True, axis=-1, inplace=False)


def _segments(flat, sizes):
    return [flat[..., o:o + n] for o, n in zip(_offsets(sizes), sizes)]


def flat_identity_encode(flat, sizes, frac=TOPK_FRAC):
    return {"v": flat}


def flat_identity_decode(payload, sizes, frac=TOPK_FRAC):
    return payload["v"]


def flat_identity_nbytes(sizes, frac=TOPK_FRAC):
    return 4 * sum(sizes)


def flat_quant8_encode(flat, sizes, frac=TOPK_FRAC):
    qs, scales = [], []
    for seg in _segments(flat, sizes):
        scale = jnp.maximum(jnp.max(jnp.abs(seg), axis=-1), 1e-12) / 127.0
        q = jnp.clip(jnp.round(seg / scale[..., None]), -127, 127
                     ).astype(jnp.int8)
        qs.append(q)
        scales.append(scale.astype(jnp.float32))
    return {"q": jnp.concatenate(qs, axis=-1),
            "scale": jnp.stack(scales, axis=-1)}


def flat_quant8_decode(payload, sizes, frac=TOPK_FRAC):
    outs = [seg.astype(jnp.float32) * payload["scale"][..., i:i + 1]
            for i, seg in enumerate(_segments(payload["q"], sizes))]
    return jnp.concatenate(outs, axis=-1)


def flat_quant8_nbytes(sizes, frac=TOPK_FRAC):
    return sum(sizes) + 4 * len(sizes)


def flat_topk_encode(flat, sizes, frac=TOPK_FRAC):
    keeps, vals = [], []
    for n, seg in zip(sizes, _segments(flat, sizes)):
        keep = topk_keep_mask(seg, leaf_topk_k(n, frac))
        keeps.append(keep)
        vals.append(jnp.where(keep, seg, 0.0))
    return {"keep": jnp.concatenate(keeps, axis=-1),
            "val": jnp.concatenate(vals, axis=-1)}


def flat_topk_decode(payload, sizes, frac=TOPK_FRAC):
    return payload["val"]


def flat_topk_nbytes(sizes, frac=TOPK_FRAC):
    return sum((4 + 4) * leaf_topk_k(n, frac) for n in sizes)


def flat_quant8_topk_encode(flat, sizes, frac=TOPK_FRAC):
    keeps, qs, scales = [], [], []
    for n, seg in zip(sizes, _segments(flat, sizes)):
        keep = topk_keep_mask(seg, leaf_topk_k(n, frac))
        kept = jnp.where(keep, seg, 0.0)
        # max|kept| == max|seg| (top-k always contains the row max), which
        # is exactly the oracle's scale over the k selected values.
        scale = jnp.maximum(jnp.max(jnp.abs(kept), axis=-1), 1e-12) / 127.0
        q = jnp.clip(jnp.round(kept / scale[..., None]), -127, 127
                     ).astype(jnp.int8)
        keeps.append(keep)
        qs.append(q)
        scales.append(scale.astype(jnp.float32))
    return {"keep": jnp.concatenate(keeps, axis=-1),
            "q": jnp.concatenate(qs, axis=-1),
            "scale": jnp.stack(scales, axis=-1)}


def flat_quant8_topk_decode(payload, sizes, frac=TOPK_FRAC):
    outs = [seg.astype(jnp.float32) * payload["scale"][..., i:i + 1]
            for i, seg in enumerate(_segments(payload["q"], sizes))]
    return jnp.concatenate(outs, axis=-1)


def flat_quant8_topk_nbytes(sizes, frac=TOPK_FRAC):
    return sum((4 + 1) * leaf_topk_k(n, frac) + 4 for n in sizes)


class FlatCodec(NamedTuple):
    encode: Callable[..., PyTree]   # (flat, sizes, frac) -> payload
    decode: Callable[..., jax.Array]  # (payload, sizes, frac) -> flat
    nbytes: Callable[..., int]      # (sizes, frac) -> wire bytes (static)


FLAT_CODECS = {
    "identity": FlatCodec(flat_identity_encode, flat_identity_decode,
                          flat_identity_nbytes),
    "quant8": FlatCodec(flat_quant8_encode, flat_quant8_decode,
                        flat_quant8_nbytes),
    "topk": FlatCodec(flat_topk_encode, flat_topk_decode, flat_topk_nbytes),
    "quant8_topk": FlatCodec(flat_quant8_topk_encode, flat_quant8_topk_decode,
                             flat_quant8_topk_nbytes),
}


def flat_roundtrip(codec: str, flat: jax.Array, sizes: tuple[int, ...],
                   frac: float = TOPK_FRAC) -> jax.Array:
    """Encode->decode the raveled delta; jit/vmap-safe, fixed shapes."""
    c = FLAT_CODECS[codec]
    return c.decode(c.encode(flat, sizes, frac), sizes, frac)


def flat_codec_roundtrip(codec: str, w_new: PyTree, w_ref: PyTree) -> PyTree:
    """Tree-level roundtrip through the flat layer — the jittable twin of
    `codec_roundtrip`, bitwise-equal to it."""
    delta = tree_sub(w_new, w_ref)
    flat, unravel = ravel_pytree(delta)
    rt = flat_roundtrip(codec, flat, flat_sizes(delta))
    return tree_add(w_ref, unravel(rt))


def flat_codec_nbytes(codec: str, tree: PyTree) -> int:
    """Static wire size via the flat registry — equals `codec_nbytes`."""
    return FLAT_CODECS[codec].nbytes(flat_sizes(tree))

"""Client-upload compression codecs — the paper's Related-Work contrast.

GreedyFed reduces communication by selecting FEWER/BETTER clients; the
orthogonal line of work ([2],[3] in the paper) compresses each upload.
Implementing both lets benchmarks/comm_efficiency.py put the paper's claim
in bytes: rounds-to-accuracy x bytes-per-round for selection vs compression
vs both.

Codecs are pytree -> (payload, aux) encoders with exact byte accounting and
a decode that reconstructs the (lossy) update:

  * identity        — float32 baseline
  * quant8          — per-leaf symmetric int8 quantisation (4x)
  * topk            — magnitude top-k sparsification with int32 indices
                      ([3], Stich et al.), k as a fraction of each leaf
  * quant8_topk     — both (sparsify then quantise values)

All codecs are unbiased-ish lossy maps applied to the *delta* w_k - w^t
(deltas compress far better than raw weights), matching standard practice.
"""
from __future__ import annotations

from functools import partial
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.aggregation import tree_add, tree_sub

PyTree = Any


class Encoded(NamedTuple):
    payload: PyTree      # codec-specific representation
    nbytes: int          # exact wire size of the payload


def _leaf_bytes(x: jax.Array) -> int:
    return int(x.size) * x.dtype.itemsize


# ----------------------------------------------------------- identity ------
def identity_encode(delta: PyTree) -> Encoded:
    return Encoded(delta, sum(_leaf_bytes(l) for l in jax.tree.leaves(delta)))


def identity_decode(enc: Encoded) -> PyTree:
    return enc.payload


# ------------------------------------------------------------- quant8 ------
def quant8_encode(delta: PyTree) -> Encoded:
    def enc(leaf):
        scale = jnp.maximum(jnp.max(jnp.abs(leaf)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(leaf / scale), -127, 127).astype(jnp.int8)
        return {"q": q, "scale": scale.astype(jnp.float32)}

    payload = jax.tree.map(enc, delta, is_leaf=lambda x: isinstance(x, jax.Array))
    nbytes = sum(int(l["q"].size) + 4
                 for l in jax.tree.leaves(payload,
                                          is_leaf=lambda x: isinstance(x, dict)
                                          and "q" in x))
    return Encoded(payload, nbytes)


def quant8_decode(enc: Encoded) -> PyTree:
    def dec(l):
        return l["q"].astype(jnp.float32) * l["scale"]

    return jax.tree.map(dec, enc.payload,
                        is_leaf=lambda x: isinstance(x, dict) and "q" in x)


# --------------------------------------------------------------- topk ------
def topk_encode(delta: PyTree, frac: float = 0.1) -> Encoded:
    def enc(leaf):
        flat = leaf.reshape(-1)
        k = max(1, int(flat.size * frac))
        vals, idx = jax.lax.top_k(jnp.abs(flat), k)
        return {"idx": idx.astype(jnp.int32), "val": flat[idx],
                "shape": leaf.shape}

    payload = jax.tree.map(enc, delta, is_leaf=lambda x: isinstance(x, jax.Array))
    nbytes = sum(int(l["idx"].size) * 4 + _leaf_bytes(l["val"])
                 for l in jax.tree.leaves(
                     payload, is_leaf=lambda x: isinstance(x, dict)
                     and "idx" in x))
    return Encoded(payload, nbytes)


def topk_decode(enc: Encoded) -> PyTree:
    def dec(l):
        flat = jnp.zeros(int(jnp.prod(jnp.asarray(l["shape"]))),
                         l["val"].dtype)
        return flat.at[l["idx"]].set(l["val"]).reshape(l["shape"])

    return jax.tree.map(dec, enc.payload,
                        is_leaf=lambda x: isinstance(x, dict) and "idx" in x)


# ----------------------------------------------------------- combined ------
def quant8_topk_encode(delta: PyTree, frac: float = 0.1) -> Encoded:
    sparse = topk_encode(delta, frac)

    def q(l):
        scale = jnp.maximum(jnp.max(jnp.abs(l["val"])), 1e-12) / 127.0
        return {**l, "val": jnp.clip(jnp.round(l["val"] / scale), -127, 127
                                     ).astype(jnp.int8), "scale": scale}

    payload = jax.tree.map(q, sparse.payload,
                           is_leaf=lambda x: isinstance(x, dict) and "idx" in x)
    nbytes = sum(int(l["idx"].size) * (4 + 1) + 4
                 for l in jax.tree.leaves(
                     payload, is_leaf=lambda x: isinstance(x, dict)
                     and "idx" in x))
    return Encoded(payload, nbytes)


def quant8_topk_decode(enc: Encoded) -> PyTree:
    def dec(l):
        vals = l["val"].astype(jnp.float32) * l["scale"]
        flat = jnp.zeros(int(jnp.prod(jnp.asarray(l["shape"]))), jnp.float32)
        return flat.at[l["idx"]].set(vals).reshape(l["shape"])

    return jax.tree.map(dec, enc.payload,
                        is_leaf=lambda x: isinstance(x, dict) and "idx" in x)


CODECS = {
    "identity": (identity_encode, identity_decode),
    "quant8": (quant8_encode, quant8_decode),
    "topk": (partial(topk_encode, frac=0.1), topk_decode),
    "quant8_topk": (partial(quant8_topk_encode, frac=0.1), quant8_topk_decode),
}


def compress_update(codec: str, w_new: PyTree, w_ref: PyTree
                    ) -> tuple[PyTree, int]:
    """Encode w_new relative to w_ref; return (reconstructed w_new, bytes).

    The server applies the lossy reconstruction — exactly what it would
    receive over the wire.
    """
    enc_fn, dec_fn = CODECS[codec]
    enc = enc_fn(tree_sub(w_new, w_ref))
    return tree_add(w_ref, dec_fn(enc)), enc.nbytes


def codec_roundtrip(codec: str, w_new: PyTree, w_ref: PyTree) -> PyTree:
    """Pure-array encode->decode (no byte count): safe to trace under
    jit/vmap, e.g. per-cohort inside the fused round engine."""
    enc_fn, dec_fn = CODECS[codec]
    return tree_add(w_ref, dec_fn(enc_fn(tree_sub(w_new, w_ref))))


def codec_nbytes(codec: str, tree: PyTree) -> int:
    """Wire size of one encoded update for a model of `tree`'s shapes.

    Every codec's byte count depends on leaf shapes only, so it is a
    per-run constant — computed once here instead of per client per round.
    """
    enc_fn, _ = CODECS[codec]
    return enc_fn(jax.tree.map(jnp.zeros_like, tree)).nbytes

"""Client data partitioning: Dirichlet(alpha) label skew x power-law sizes.

Paper Section IV "Data Heterogeneity":
  * label distribution of client k ~ Dirichlet(alpha) over the 10 classes;
    alpha in {1e-4, 0.1, 100} (1e-4 ~ one class per client, 100 ~ uniform);
  * client sizes n_k = q_k * n_train with q_k ~ P(x) = 3x^2 on (0,1),
    normalised to sum 1 (as in Power-of-Choice [7]).
"""
from __future__ import annotations

import numpy as np


def power_law_fractions(n_clients: int, rng: np.random.Generator,
                        min_samples_frac: float = 1e-4) -> np.ndarray:
    """q_k sampled from density 3x^2 (inverse-CDF: U^(1/3)), normalised."""
    q = rng.random(n_clients) ** (1.0 / 3.0)
    q = np.maximum(q, min_samples_frac)
    return q / q.sum()


def dirichlet_partition(
    labels: np.ndarray,
    n_clients: int,
    alpha: float,
    rng: np.random.Generator,
    fractions: np.ndarray | None = None,
    min_per_client: int = 2,
) -> list[np.ndarray]:
    """Return per-client index arrays into `labels`.

    Each client draws a label distribution p_k ~ Dirichlet(alpha * 1_C) and a
    size n_k from the power-law fractions, then fills its quota by sampling
    classes from p_k out of the remaining pool (falling back to whatever
    classes still have samples).
    """
    n = labels.shape[0]
    classes = np.unique(labels)
    if fractions is None:
        fractions = power_law_fractions(n_clients, rng)
    sizes = np.maximum((fractions * n).astype(int), min_per_client)

    pools = {int(c): list(rng.permutation(np.where(labels == c)[0])) for c in classes}
    # Dirichlet with very small alpha underflows to nan in np; clip.
    a = max(alpha, 1e-6)
    out: list[np.ndarray] = []
    for k in range(n_clients):
        p = rng.dirichlet(np.full(classes.shape[0], a))
        take: list[int] = []
        for _ in range(sizes[k]):
            avail = [i for i, c in enumerate(classes) if pools[int(c)]]
            if not avail:
                break
            pa = p[avail]
            s = pa.sum()
            pa = pa / s if s > 1e-12 else np.full(len(avail), 1.0 / len(avail))
            ci = int(rng.choice(avail, p=pa))
            take.append(pools[int(classes[ci])].pop())
        if len(take) < min_per_client:  # top up from global remainder
            for c in classes:
                while pools[int(c)] and len(take) < min_per_client:
                    take.append(pools[int(c)].pop())
        out.append(np.asarray(take, np.int64))
    return out


def partition_summary(parts: list[np.ndarray], labels: np.ndarray) -> dict:
    sizes = np.array([p.size for p in parts])
    ent = []
    for p in parts:
        if p.size == 0:
            ent.append(0.0)
            continue
        _, cnt = np.unique(labels[p], return_counts=True)
        q = cnt / cnt.sum()
        ent.append(float(-(q * np.log(q + 1e-12)).sum()))
    return {
        "sizes_min": int(sizes.min()), "sizes_max": int(sizes.max()),
        "sizes_mean": float(sizes.mean()),
        "label_entropy_mean": float(np.mean(ent)),  # ~0 => one class/client
    }

"""Client data partitioning: Dirichlet(alpha) label skew x power-law sizes.

Paper Section IV "Data Heterogeneity":
  * label distribution of client k ~ Dirichlet(alpha) over the 10 classes;
    alpha in {1e-4, 0.1, 100} (1e-4 ~ one class per client, 100 ~ uniform);
  * client sizes n_k = q_k * n_train with q_k ~ P(x) = 3x^2 on (0,1),
    normalised to sum 1 (as in Power-of-Choice [7]).
"""
from __future__ import annotations

import numpy as np


def power_law_fractions(n_clients: int, rng: np.random.Generator,
                        min_samples_frac: float = 1e-4) -> np.ndarray:
    """q_k sampled from density 3x^2 (inverse-CDF: U^(1/3)), normalised."""
    q = rng.random(n_clients) ** (1.0 / 3.0)
    q = np.maximum(q, min_samples_frac)
    return q / q.sum()


def dirichlet_partition(
    labels: np.ndarray,
    n_clients: int,
    alpha: float,
    rng: np.random.Generator,
    fractions: np.ndarray | None = None,
    min_per_client: int = 2,
) -> list[np.ndarray]:
    """Return per-client index arrays into `labels`.

    Each client draws a label distribution p_k ~ Dirichlet(alpha * 1_C) and a
    size n_k from the power-law fractions, then fills its quota by sampling
    classes from p_k out of the remaining pool (falling back to whatever
    classes still have samples).
    """
    n = labels.shape[0]
    classes = np.unique(labels)
    if fractions is None:
        fractions = power_law_fractions(n_clients, rng)
    sizes = np.maximum((fractions * n).astype(int), min_per_client)

    # Per-class pools as permuted arrays consumed front-to-cursor: a
    # client's grant of g samples from class c is the next g entries of a
    # uniformly random order — the same distribution as g sequential
    # `pool.pop()` draws, at O(1) per sample instead of O(C) python work.
    pools = [rng.permutation(np.where(labels == c)[0]) for c in classes]
    cursors = np.zeros(len(classes), np.int64)
    remaining = np.asarray([p.size for p in pools], np.int64)
    # Dirichlet with very small alpha underflows to nan in np; clip.
    a = max(alpha, 1e-6)
    out: list[np.ndarray] = []
    for k in range(n_clients):
        p = rng.dirichlet(np.full(classes.shape[0], a))
        take_parts: list[np.ndarray] = []
        need = int(sizes[k])
        # whole-quota batched class draws: each pass either fills the
        # remaining quota or exhausts >= 1 class, so <= C+1 passes/client
        while need > 0:
            avail = np.where(remaining > 0)[0]
            if avail.size == 0:
                break
            pa = p[avail]
            s = pa.sum()
            pa = (pa / s if s > 1e-12
                  else np.full(avail.size, 1.0 / avail.size))
            cnt = np.bincount(rng.choice(avail.size, size=need, p=pa),
                              minlength=avail.size)
            grant = np.minimum(cnt, remaining[avail])
            for ci, g in zip(avail, grant):
                if g:
                    take_parts.append(pools[ci][cursors[ci]:cursors[ci] + g])
            cursors[avail] += grant
            remaining[avail] -= grant
            need -= int(grant.sum())
        take = (np.concatenate(take_parts) if take_parts
                else np.empty(0, np.int64))
        if take.size < min_per_client:  # top up from global remainder
            for ci in range(len(classes)):
                g = min(min_per_client - take.size, int(remaining[ci]))
                if g > 0:
                    take = np.concatenate(
                        [take, pools[ci][cursors[ci]:cursors[ci] + g]])
                    cursors[ci] += g
                    remaining[ci] -= g
        out.append(np.asarray(take, np.int64))
    return out


# --------------------------------------------------------------------------
# padded-stack blocks: the (N, cap, ...) layout the engines consume, built
# one client-axis slice at a time so a client-sharded run materialises only
# each device's own rows (server.setup_run passes these as the
# make_array_from_callback per-shard builders; the dense path is the
# lo=0, hi=N special case)
# --------------------------------------------------------------------------

def client_cap(parts: list[np.ndarray]) -> int:
    """Padded per-client capacity: the largest client's sample count."""
    return max(int(p.size) for p in parts)


def padded_x_block(x: np.ndarray, parts: list[np.ndarray], cap: int,
                   lo: int, hi: int) -> np.ndarray:
    """(hi-lo, cap, ...) float32 rows [lo, hi) of the padded data stack;
    rows past len(parts) are pad clients (all zeros, n_valid 0)."""
    out = np.zeros((hi - lo, cap) + x.shape[1:], np.float32)
    for i in range(lo, min(hi, len(parts))):
        p = parts[i]
        out[i - lo, : p.size] = x[p]
    return out


def padded_y_block(y: np.ndarray, parts: list[np.ndarray], cap: int,
                   lo: int, hi: int) -> np.ndarray:
    """(hi-lo, cap) int32 label rows [lo, hi) of the padded stack."""
    out = np.zeros((hi - lo, cap), np.int32)
    for i in range(lo, min(hi, len(parts))):
        p = parts[i]
        out[i - lo, : p.size] = y[p]
    return out


def valid_counts(parts: list[np.ndarray], lo: int, hi: int) -> np.ndarray:
    """(hi-lo,) int32 per-client sample counts for rows [lo, hi)."""
    out = np.zeros((hi - lo,), np.int32)
    for i in range(lo, min(hi, len(parts))):
        out[i - lo] = parts[i].size
    return out


def partition_summary(parts: list[np.ndarray], labels: np.ndarray) -> dict:
    sizes = np.array([p.size for p in parts])
    ent = []
    for p in parts:
        if p.size == 0:
            ent.append(0.0)
            continue
        _, cnt = np.unique(labels[p], return_counts=True)
        q = cnt / cnt.sum()
        ent.append(float(-(q * np.log(q + 1e-12)).sum()))
    return {
        "sizes_min": int(sizes.min()), "sizes_max": int(sizes.max()),
        "sizes_mean": float(sizes.mean()),
        "label_entropy_mean": float(np.mean(ent)),  # ~0 => one class/client
    }

"""jit'd public wrapper: pytree-aware batched subset averaging.

`weighted_avg(stacked_tree, weights)` flattens the stacked client pytree to
one (M, D_total) matrix view per leaf, runs the Pallas kernel per leaf (or
the jnp reference off-TPU), and rebuilds R averaged pytrees stacked on a
leading subset axis.
"""
from __future__ import annotations

from functools import partial
from typing import Any

import jax

from repro.kernels import default_interpret, pad_to
from repro.kernels.weighted_avg.kernel import weighted_avg_kernel
from repro.kernels.weighted_avg.ref import weighted_avg_ref

PyTree = Any


@partial(jax.jit, static_argnames=("use_kernel", "interpret", "block_d"))
def weighted_avg(stacked_tree: PyTree, weights: jax.Array, *,
                 use_kernel: bool = True, interpret: bool | None = None,
                 block_d: int = 2048) -> PyTree:
    """stacked_tree leaves (M, *s); weights (R, M) -> leaves (R, *s).

    `interpret=None` derives from the backend (compile natively on TPU,
    interpret elsewhere).
    """
    if interpret is None:
        interpret = default_interpret()

    def one(leaf: jax.Array) -> jax.Array:
        m = leaf.shape[0]
        flat = leaf.reshape(m, -1)
        d = flat.shape[1]
        if not use_kernel or d < block_d:
            out = weighted_avg_ref(flat, weights.astype(flat.dtype))
        else:
            padded = pad_to(flat, block_d)
            out = weighted_avg_kernel(padded, weights.astype(flat.dtype),
                                      block_d=block_d, interpret=interpret)
            out = out[:, :d]
        return out.reshape((weights.shape[0],) + leaf.shape[1:])

    return jax.tree.map(one, stacked_tree)

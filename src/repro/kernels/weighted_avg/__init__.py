from repro.kernels.weighted_avg.ops import weighted_avg
from repro.kernels.weighted_avg.ref import weighted_avg_ref

__all__ = ["weighted_avg", "weighted_avg_ref"]

"""Pallas TPU kernel: fused multi-model weighted averaging.

The ModelAverage hot-spot of GTG-Shapley: a round evaluates O(T_mc * M^2)
subset averages of the SAME stacked client-update matrix W (M, D) under
different weight vectors.  The kernel processes a whole *batch* of R weight
vectors per pass over W, so HBM traffic for the weights is amortised R-fold
versus calling a plain weighted sum per subset (the GPU reference re-reads
W per subset — DESIGN.md §3).

Layout:
    stacked  (M, D)  — client models flattened to a single parameter axis
    weights  (R, M)  — R normalised subset-weight rows (one per MC subset)
    out      (R, D)  — out[r] = sum_k weights[r,k] * stacked[k]

Grid: (D // BLOCK_D,).  Per step the kernel streams a (M, BLOCK_D) tile of W
into VMEM once and contracts it against the full (R, M) weight matrix (tiny,
kept resident in VMEM) on the MXU: (R, M) @ (M, BLOCK_D).

BLOCK_D is 128-aligned for the MXU; M (<= ~32 clients) and R ride in the
sublane dimension.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_D = 2048  # lane-dim tile; multiple of 128 (MXU) and 8*128 (VREG)


def _wavg_kernel(w_ref, stacked_ref, out_ref):
    # w_ref: (R, M) in VMEM; stacked_ref: (M, BLOCK_D); out_ref: (R, BLOCK_D)
    w = w_ref[...].astype(jnp.float32)
    tile = stacked_ref[...].astype(jnp.float32)
    out_ref[...] = jnp.dot(
        w, tile, preferred_element_type=jnp.float32
    ).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def weighted_avg_kernel(stacked: jax.Array, weights: jax.Array, *,
                        block_d: int = BLOCK_D,
                        interpret: bool = False) -> jax.Array:
    """stacked (M, D) x weights (R, M) -> (R, D).  D % block_d == 0."""
    m, d = stacked.shape
    r = weights.shape[0]
    assert weights.shape == (r, m), (weights.shape, (r, m))
    assert d % block_d == 0, (d, block_d)

    grid = (d // block_d,)
    return pl.pallas_call(
        _wavg_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((r, m), lambda i: (0, 0)),          # weights resident
            pl.BlockSpec((m, block_d), lambda i: (0, i)),    # stream W tiles
        ],
        out_specs=pl.BlockSpec((r, block_d), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((r, d), stacked.dtype),
        interpret=interpret,
    )(weights, stacked)

"""Pure-jnp oracle for the weighted_avg kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def weighted_avg_ref(stacked: jax.Array, weights: jax.Array) -> jax.Array:
    """stacked (M, D) x weights (R, M) -> (R, D) in f32 accumulation."""
    out = jnp.einsum("rm,md->rd", weights.astype(jnp.float32),
                     stacked.astype(jnp.float32))
    return out.astype(stacked.dtype)

"""Pallas TPU kernel: fused single-pass delta-codec roundtrip.

The scan engine's cohort stage compresses every client's update delta each
round (round_engine.py).  The old path was a per-leaf chain of XLA kernels
— abs-max pass, quant pass, dequant pass, full-row `lax.top_k` (a sort)
plus a dense zeros+scatter — each materialising an (M, D) intermediate in
HBM.  Here the whole roundtrip is ONE pass: each grid step DMAs one row
(1, D) into VMEM, computes abs-max -> int8 quantise -> dequantise (and the
exact top-k keep mask for the sparse codecs) entirely on-chip, and writes
the reconstructed row back.  HBM traffic is the floor: read D, write D.

Top-k without a sort: |x| >= 0, so the f32 bit pattern reinterpreted as
int32 is monotone in the float value (sign bit clear => signed compare ==
float compare) and bit-equality == float equality.  The k-th largest key
is found by MSB descent — build the largest threshold t, bit by bit from
bit 30 down, keeping a bit iff count(key >= t|bit) >= k; each step is one
compare+sum over the VMEM-resident row.  Ties at the threshold are broken
lowest-index-first (the `lax.top_k` contract) by a second MSB descent over
the tied column indices.  ~2*31 vector passes over VMEM, zero HBM traffic
beyond the single streaming read/write.

Padding: rows are zero-padded to a lane multiple by the ops wrapper; a
static `d_true` masks pad columns out of the abs-max and the top-k
candidate pool (a pad key of -1 sorts below every valid key, so padding
never steals a keep slot from a real element).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128  # lane-dim alignment for the (1, D) row blocks


def _kth_largest(key: jax.Array, k: jax.Array | int, nbits: int) -> jax.Array:
    """k-th largest entry of int32 `key` (values in [-1, 2^nbits)): the
    largest t with count(key >= t) >= k, found by MSB descent.  Exact;
    requires at least k entries >= 0."""
    def body(i, t):
        cand = t | jnp.int32(1 << (nbits - 1 - i))
        cnt = jnp.sum((key >= cand).astype(jnp.int32))
        return jnp.where(cnt >= k, cand, t)

    return jax.lax.fori_loop(0, nbits, body, jnp.int32(0))


def _codec_kernel(x_ref, out_ref, *, codec: str, k: int, d_true: int):
    x = x_ref[...].astype(jnp.float32)                      # (1, d_pad)
    d_pad = x.shape[-1]
    col = jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
    valid = col < d_true
    absx = jnp.where(valid, jnp.abs(x), 0.0)
    if codec in ("quant8", "quant8_topk"):
        scale = jnp.maximum(jnp.max(absx), 1e-12) / 127.0
        q = jnp.clip(jnp.round(x / scale), -127.0, 127.0) * scale
    if codec == "quant8":
        out = q
    else:
        key = jnp.where(valid,
                        jax.lax.bitcast_convert_type(absx, jnp.int32),
                        -1)
        # finite f32 bit patterns are < 2^31, so 31 bits cover every key
        thr = _kth_largest(key, k, 31)
        above = key > thr
        r = k - jnp.sum(above.astype(jnp.int32))            # ties to keep
        tie = key == thr
        # r-th smallest tied column == d_pad minus the r-th largest of
        # (d_pad - col) over the ties
        tkey = jnp.where(tie, d_pad - col, -1)
        u = d_pad - _kth_largest(tkey, r, max(1, d_pad.bit_length()))
        keep = above | (tie & (col <= u))
        out = jnp.where(keep, x if codec == "topk" else q, 0.0)
    out_ref[...] = out.astype(out_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("codec", "k", "d_true", "interpret"))
def delta_codec_kernel(x: jax.Array, *, codec: str, k: int = 0,
                       d_true: int | None = None,
                       interpret: bool = False) -> jax.Array:
    """Roundtrip each row of x (rows, d_pad) through `codec`.

    d_pad % 128 == 0; columns >= d_true are padding (passed through the
    quantiser but excluded from abs-max and top-k).  `k` is the static
    per-row keep count for the sparse codecs.
    """
    rows, d_pad = x.shape
    assert d_pad % LANES == 0, (d_pad, LANES)
    if d_true is None:
        d_true = d_pad
    assert 0 < d_true <= d_pad, (d_true, d_pad)

    kernel = functools.partial(_codec_kernel, codec=codec, k=k, d_true=d_true)
    return pl.pallas_call(
        kernel,
        grid=(rows,),
        in_specs=[pl.BlockSpec((1, d_pad), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, d_pad), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x)

"""Fused upload-delta codec roundtrip for the cohort stage (DESIGN.md §18).

  * `kernel.py` — Pallas TPU single-pass roundtrip: per row, abs-max ->
                  int8 quantise -> dequantise (+ exact sort-free top-k
                  masking via MSB descent over f32 magnitude bits), one
                  HBM read and one write total;
  * `ref.py`    — the rowwise jnp oracle, bitwise-equal per-row semantics
                  to `federated.compression`'s per-leaf codecs;
  * `ops.py`    — the public pytree wrapper the engines call (kernel on
                  TPU, fused ref elsewhere).
"""
from repro.kernels.delta_codec.ops import delta_codec_roundtrip

__all__ = ["delta_codec_roundtrip"]

"""Public wrapper: fused upload-codec roundtrip on a stacked cohort pytree.

`delta_codec_roundtrip(stacked, params, codec)` replaces the engines' old
per-client `vmap(codec_roundtrip)` chain: for each leaf, the (M, *s)
stacked client weights minus the (*s,) reference become an (M, d) delta
matrix, roundtripped in one fused pass, and added back.  Per-leaf k for
the sparse codecs follows the oracle's rule (`leaf_topk_k`), so results
match `federated.compression` bitwise up to jit fusion of the final add.

Routing: the Pallas kernel keeps a whole (1, d) row resident in VMEM, so
it serves native-TPU backends for mid-size leaves; tiny leaves, oversize
leaves, and non-TPU backends take the rowwise jnp ref — still one XLA
fusion per leaf instead of the old multi-kernel chain (the interpret-mode
emulation of the in-kernel MSB-descent select would be pure overhead).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any

import jax

from repro.kernels import default_interpret, pad_to
from repro.kernels.delta_codec.kernel import LANES, delta_codec_kernel
from repro.kernels.delta_codec.ref import delta_codec_ref

PyTree = Any

MIN_KERNEL_D = 2048     # below this the ref fusion wins
MAX_KERNEL_D = 1 << 18  # a (1, d) f32 row + select temporaries must fit VMEM


@partial(jax.jit, static_argnames=("codec", "frac", "use_kernel",
                                   "interpret"))
def delta_codec_roundtrip(stacked: PyTree, params: PyTree, codec: str, *,
                          frac: float | None = None,
                          use_kernel: bool | None = None,
                          interpret: bool | None = None) -> PyTree:
    """stacked leaves (M, *s), params leaves (*s,) -> roundtripped stack.

    `frac=None` takes the oracle's `TOPK_FRAC`; `interpret=None` derives
    from the backend; `use_kernel=None` enables the Pallas kernel exactly
    where it compiles natively (TPU).
    """
    # deferred: compression sits under repro.federated, whose __init__
    # pulls in the engines — which import this package at module scope
    from repro.federated.compression import TOPK_FRAC, leaf_topk_k

    if codec == "identity":
        return stacked
    if frac is None:
        frac = TOPK_FRAC
    if interpret is None:
        interpret = default_interpret()
    if use_kernel is None:
        use_kernel = not interpret

    def one(leaf: jax.Array, ref_leaf: jax.Array) -> jax.Array:
        m = leaf.shape[0]
        d = math.prod(leaf.shape[1:])
        delta = leaf.reshape(m, d) - ref_leaf.reshape(1, d)
        k = leaf_topk_k(d, frac) if codec != "quant8" else 0
        if use_kernel and MIN_KERNEL_D <= d <= MAX_KERNEL_D:
            rt = delta_codec_kernel(pad_to(delta, LANES), codec=codec, k=k,
                                    d_true=d, interpret=interpret)[:, :d]
        else:
            rt = delta_codec_ref(delta, codec, k=k)
        return (ref_leaf.reshape(1, d) + rt).reshape(leaf.shape)

    return jax.tree.map(one, stacked, params)

"""jnp reference: rowwise delta-codec roundtrip on a (rows, d) matrix.

One fused XLA computation per codec — abs-max, quantise, dequantise and
(for the sparse codecs) an exact top-k keep mask — with per-row semantics
bitwise-equal to `federated.compression`'s per-leaf oracle:

  * quant8:      scale = max(max|x|, 1e-12)/127 per row; the int8 cast is
                 elided because clip(round(x/scale)) is an integer in
                 [-127, 127], exactly representable in f32 — the product
                 q * scale is bit-identical either way.
  * topk:        keep the k largest |x| per row, `lax.top_k` tie order
                 (lowest index first); dropped entries become +0.0 via
                 `where`, matching the oracle's zeros+scatter (an `x * mask`
                 would leak -0.0 for negative x).
  * quant8_topk: sparsify then quantise the survivors.  The scale is the
                 row abs-max — identical to the oracle's max over the k
                 selected values, because the top-k set always contains
                 the row's largest-magnitude entry.

This is also the serving path off-TPU: one fused computation per leaf
instead of the old per-leaf encode/decode chain's separate value gather
and dense zeros+scatter dispatchs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _keep_mask(absx: jax.Array, k: int) -> jax.Array:
    """(rows, d) |x| -> boolean keep mask of exactly k entries per row.

    Scattered from `lax.top_k`'s own index set (ties lowest-index-first)
    — the oracle's set by construction.  Consuming top_k's indices whole
    keeps XLA's fast partial TopK; slicing out the k-th value as a
    threshold would defeat the TopK rewrite and lower to a full sort.
    """
    _, idx = jax.lax.top_k(absx, k)
    keep = jnp.zeros(absx.shape, bool)
    return jnp.put_along_axis(keep, idx, True, axis=-1, inplace=False)


def delta_codec_ref(x: jax.Array, codec: str, k: int = 0) -> jax.Array:
    """Roundtrip (encode -> decode) each row of x (rows, d) through codec."""
    orig = x.dtype
    x = x.astype(jnp.float32)
    absx = jnp.abs(x)
    if codec == "quant8":
        scale = jnp.maximum(jnp.max(absx, axis=-1, keepdims=True),
                            1e-12) / 127.0
        out = jnp.clip(jnp.round(x / scale), -127.0, 127.0) * scale
    elif codec == "topk":
        out = jnp.where(_keep_mask(absx, k), x, 0.0)
    elif codec == "quant8_topk":
        keep = _keep_mask(absx, k)
        scale = jnp.maximum(jnp.max(absx, axis=-1, keepdims=True),
                            1e-12) / 127.0
        out = jnp.where(keep,
                        jnp.clip(jnp.round(x / scale), -127.0, 127.0) * scale,
                        0.0)
    else:
        raise ValueError(f"unknown delta codec {codec!r}")
    return out.astype(orig)

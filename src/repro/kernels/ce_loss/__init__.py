from repro.kernels.ce_loss.ops import ce_loss
from repro.kernels.ce_loss.ref import ce_loss_ref

__all__ = ["ce_loss", "ce_loss_ref"]

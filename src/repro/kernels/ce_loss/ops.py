"""jit'd public wrapper for the fused CE utility evaluation."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import default_interpret
from repro.kernels.ce_loss.kernel import ce_loss_kernel
from repro.kernels.ce_loss.ref import ce_loss_ref

NEG_INF = -1e30


@partial(jax.jit, static_argnames=("use_kernel", "interpret", "block_v"))
def ce_loss(logits: jax.Array, labels: jax.Array, *,
            use_kernel: bool = True, interpret: bool | None = None,
            block_v: int = 2048) -> jax.Array:
    """Mean CE over rows; (R, V) logits, (R,) int labels -> scalar f32.

    Pads the vocab axis to the kernel tile (padded logits masked to -inf,
    which contribute exp(-inf)=0 to the denominator).  `interpret=None`
    derives from the backend (compile natively on TPU, interpret
    elsewhere).
    """
    if interpret is None:
        interpret = default_interpret()
    r, v = logits.shape
    if not use_kernel or v < block_v:
        return jnp.mean(ce_loss_ref(logits, labels))
    pad = (-v) % block_v
    if pad:
        fill = jnp.full((r, pad), NEG_INF, logits.dtype)
        logits = jnp.concatenate([logits, fill], axis=1)
    per = ce_loss_kernel(logits, labels, block_v=block_v, interpret=interpret)
    return jnp.mean(per)

"""Pallas TPU kernel: fused softmax cross-entropy (the GTG utility eval).

U(S) = -L(w_S; D_val) is evaluated once per Monte-Carlo subset — the second
hot-spot of Alg. 2.  The fused kernel computes per-row CE without ever
materialising the (rows, vocab) softmax in HBM: the vocab axis is tiled into
VMEM blocks and reduced online (running max + rescaled sum — the same
recurrence as flash attention), while the gold-label logit is picked up by a
masked reduction in the same pass.

Layout:
    logits (R, V) bf16/f32, labels (R,) int32 -> per-row loss (R,) f32
Grid: (V // BLOCK_V,) — each step streams an (R, BLOCK_V) tile; the running
(m, s, gold) state lives in three (R, 1) f32 VMEM accumulators (output
aliasing across grid steps on the same block).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_V = 2048
NEG_INF = -1e30


def _ce_kernel(logits_ref, labels_ref, m_ref, s_ref, gold_ref):
    i = pl.program_id(0)
    tile = logits_ref[...].astype(jnp.float32)           # (R, BLOCK_V)
    r, bv = tile.shape
    labels = labels_ref[...].reshape(r)                  # (R,)

    @pl.when(i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        s_ref[...] = jnp.zeros_like(s_ref)
        gold_ref[...] = jnp.zeros_like(gold_ref)

    # online logsumexp over the vocab tiles
    m_prev = m_ref[...]                                  # (R, 1)
    m_new = jnp.maximum(m_prev, jnp.max(tile, axis=-1, keepdims=True))
    s_ref[...] = s_ref[...] * jnp.exp(m_prev - m_new) + jnp.sum(
        jnp.exp(tile - m_new), axis=-1, keepdims=True)
    m_ref[...] = m_new

    # gold logit: masked pick within this tile
    col = jax.lax.broadcasted_iota(jnp.int32, (r, bv), 1) + i * bv
    hit = col == labels[:, None]
    gold_ref[...] += jnp.sum(jnp.where(hit, tile, 0.0), axis=-1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("block_v", "interpret"))
def ce_loss_kernel(logits: jax.Array, labels: jax.Array, *,
                   block_v: int = BLOCK_V,
                   interpret: bool = False) -> jax.Array:
    """(R, V) x (R,) -> per-row CE loss (R,) f32.  V % block_v == 0."""
    r, v = logits.shape
    assert v % block_v == 0, (v, block_v)
    grid = (v // block_v,)

    m, s, gold = pl.pallas_call(
        _ce_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((r, block_v), lambda i: (0, i)),
            pl.BlockSpec((r, 1), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((r, 1), lambda i: (0, 0)),
            pl.BlockSpec((r, 1), lambda i: (0, 0)),
            pl.BlockSpec((r, 1), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((r, 1), jnp.float32),
            jax.ShapeDtypeStruct((r, 1), jnp.float32),
            jax.ShapeDtypeStruct((r, 1), jnp.float32),
        ],
        interpret=interpret,
    )(logits, labels.reshape(r, 1).astype(jnp.int32))

    logz = m[:, 0] + jnp.log(s[:, 0])
    return logz - gold[:, 0]

"""Pure-jnp oracle for the ce_loss kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ce_loss_ref(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """(R, V) x (R,) -> per-row CE (R,) f32."""
    lg = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(lg, axis=-1)
    gold = jnp.take_along_axis(lg, labels[:, None].astype(jnp.int32),
                               axis=-1)[:, 0]
    return logz - gold

from repro.kernels.flash_attention.ops import flash_attention_tpu
from repro.kernels.flash_attention.ref import attention_ref

__all__ = ["flash_attention_tpu", "attention_ref"]

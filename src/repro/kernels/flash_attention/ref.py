"""Pure-jnp oracle for the flash_attention kernel (dense scores + mask)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: int = 0) -> jax.Array:
    """q (BH, S, hd); k/v (BH, T, hd) -> (BH, S, hd)."""
    s_len, t_len = q.shape[1], k.shape[1]
    hd = q.shape[-1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * hd ** -0.5
    q_pos = jnp.arange(s_len)[:, None]
    k_pos = jnp.arange(t_len)[None, :]
    mask = jnp.ones((s_len, t_len), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask[None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)

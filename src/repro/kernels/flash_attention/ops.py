"""jit'd public wrapper: model-shaped GQA in, kernel layout out.

Folds (B, S, Hq=Kh*G, hd) GQA tensors into the kernel's (B*Kh, G*S, hd)
layout.  NOTE the fold changes query positions (query row r of group g is
token r), so instead we fold G into the BH axis by repeating KV — wrapper
keeps semantics identical to models/lm/attention.attention.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import default_interpret
from repro.kernels.flash_attention.kernel import flash_attention_kernel
from repro.kernels.flash_attention.ref import attention_ref


@partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_k",
                                   "use_kernel", "interpret"))
def flash_attention_tpu(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window: int = 0,
                        block_q: int = 512, block_k: int = 512,
                        use_kernel: bool = True,
                        interpret: bool | None = None) -> jax.Array:
    """q (B, S, Hq, hd); k/v (B, T, Kh, hd) -> (B, S, Hq, hd).

    `interpret=None` derives from the backend (compile natively on TPU,
    interpret elsewhere).
    """
    if interpret is None:
        interpret = default_interpret()
    b, s_len, hq, hd = q.shape
    t_len, kh = k.shape[1], k.shape[2]
    g = hq // kh

    # (B, S, Kh, G, hd) -> (B*Kh*G, S, hd); KV repeated per group
    qf = q.reshape(b, s_len, kh, g, hd).transpose(0, 2, 3, 1, 4)
    qf = qf.reshape(b * kh * g, s_len, hd)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3), g, axis=1).reshape(
        b * kh * g, t_len, hd)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3), g, axis=1).reshape(
        b * kh * g, t_len, hd)

    if use_kernel and s_len % block_q == 0 and t_len % block_k == 0:
        of = flash_attention_kernel(qf, kf, vf, causal=causal, window=window,
                                    block_q=block_q, block_k=block_k,
                                    interpret=interpret)
    else:
        of = attention_ref(qf, kf, vf, causal=causal, window=window)

    o = of.reshape(b, kh, g, s_len, hd).transpose(0, 3, 1, 2, 4)
    return o.reshape(b, s_len, hq, hd)

"""Pallas TPU kernel: causal flash attention with optional sliding window.

TPU-target implementation of models/lm/attention.py's pure-JAX flash path
(the oracle): online-softmax over KV blocks, O(S * BLOCK_K) VMEM, MXU-sized
tiles.  GQA is handled by folding the group into the query rows: the kernel
operates on one (batch, kv-head) pair per grid slot with q rows = G * S.

Grid: (B * Kh, S // BLOCK_Q, T // BLOCK_K) — the KV axis is the innermost
(sequential) dimension so the (m, l, acc) accumulators for a query block
live across grid steps in VMEM scratch.

Window masking: for SWA (window > 0) blocks entirely behind the window are
masked; the wrapper prunes fully-masked KV blocks from the grid bounds.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: int,
                  block_q: int, block_k: int, n_kv_blocks: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)                     # (block_q, hd)
    k = k_ref[0].astype(jnp.float32)                     # (block_k, hd)
    v = v_ref[0].astype(jnp.float32)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

    q_pos = qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    mask = jnp.ones_like(s, dtype=bool)
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                                  # (block_q, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ki == n_kv_blocks - 1)
    def _finish():
        o_ref[0] = (acc_scr[...]
                    / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret"))
def flash_attention_kernel(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, window: int = 0,
                           block_q: int = 512, block_k: int = 512,
                           interpret: bool = False) -> jax.Array:
    """q (BH, S, hd); k/v (BH, T, hd) -> (BH, S, hd).

    BH folds batch x kv-head (x GQA group into S); S % block_q == 0,
    T % block_k == 0.
    """
    bh, s_len, hd = q.shape
    t_len = k.shape[1]
    assert s_len % block_q == 0 and t_len % block_k == 0
    n_q = s_len // block_q
    n_k = t_len // block_k
    scale = hd ** -0.5

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, n_kv_blocks=n_k)

    return pl.pallas_call(
        kernel,
        grid=(bh, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s_len, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)

"""Pallas TPU kernel: streaming prefix-subset averaging (DESIGN.md §14).

Along one GTG permutation walk the prefix ModelAverage is a running sum,

    S_j = S_{j-1} + n_{pi(j)} * W[pi(j)],     wbar_j = S_j / N_j,

so the dense `(R*M, M) x (M, D)` contraction of `kernels/weighted_avg`
(O(R*M^2*D) FLOPs for the full prefix family) collapses to one gather +
cumulative sum per walk: O(R*M*D) FLOPs, the minimum to materialise the
R*M prefix models at all.

Layout:
    stacked (M, D)    — client models flattened to one parameter axis
    idx     (R*M,)    — permutations flattened walk-major (scalar prefetch)
    scale   (R*M,)    — n_k gathered in walk order (scalar prefetch)
    ncum    (R*M,)    — running subset sizes N_j per position (prefetch)
    out     (R*M, D)  — out[r*M + j] = prefix-average model j of walk r

Grid: (R, D // BLOCK_D).  Program (r, i) keeps the (M, BLOCK_D) tile of W
resident in VMEM and walks permutation r front to back, accumulating the
running sum in f32 and emitting one averaged row per step; the row gather
is a dynamic VMEM slice driven by the prefetched indices (SMEM).  The
j-loop is strictly left-to-right — that accumulation order is the
contract that makes chunked and unchunked evaluation bit-identical
(`core/shapley_batched.gtg_shapley_streaming`).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK_D = 2048  # lane-dim tile; multiple of 128 (MXU) and 8*128 (VREG)


def _prefix_kernel(idx_ref, scale_ref, ncum_ref, stacked_ref, out_ref):
    # idx/scale/ncum: (R*M,) in SMEM; stacked_ref: (M, BLOCK_D) in VMEM;
    # out_ref: (M, BLOCK_D) — walk r's M prefix models for this D-block
    r = pl.program_id(0)
    m = stacked_ref.shape[0]

    def step(j, acc):
        p = r * m + j
        row = stacked_ref[pl.ds(idx_ref[p], 1), :].astype(jnp.float32)
        acc = acc + scale_ref[p] * row
        out_ref[pl.ds(j, 1), :] = (acc / ncum_ref[p]).astype(out_ref.dtype)
        return acc

    jax.lax.fori_loop(0, m, step,
                      jnp.zeros((1, out_ref.shape[1]), jnp.float32))


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def prefix_avg_kernel(stacked: jax.Array, perms: jax.Array, n_k: jax.Array,
                      *, block_d: int = BLOCK_D,
                      interpret: bool = False) -> jax.Array:
    """stacked (M, D) x perms (R, M) x n_k (M,) -> (R*M, D) prefix models.

    D % block_d == 0 (callers pad; see ops.py).  Row r*M + j holds the
    ModelAverage of the walk prefix perms[r, :j+1].
    """
    m, d = stacked.shape
    r = perms.shape[0]
    assert perms.shape == (r, m), (perms.shape, (r, m))
    assert d % block_d == 0, (d, block_d)

    scale2 = jnp.take(n_k, perms).astype(jnp.float32)      # (R, M)
    ncum = jnp.cumsum(scale2, axis=1).reshape(-1)          # (R*M,)
    scale = scale2.reshape(-1)
    idx = perms.reshape(-1).astype(jnp.int32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(r, d // block_d),
        in_specs=[
            pl.BlockSpec((m, block_d), lambda ri, i, *_: (0, i)),  # W tiles
        ],
        out_specs=pl.BlockSpec((m, block_d), lambda ri, i, *_: (ri, i)),
    )
    return pl.pallas_call(
        _prefix_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((r * m, d), stacked.dtype),
        interpret=interpret,
    )(idx, scale, ncum, stacked)

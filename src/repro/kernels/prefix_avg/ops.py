"""jit'd public wrapper: pytree-aware streaming prefix averaging.

`prefix_avg(stacked_tree, perms, n_k)` flattens the stacked client pytree
to one (M, D_leaf) matrix view per leaf, runs the Pallas kernel per leaf
(or the jnp reference for small / off-TPU leaves), and rebuilds the R*M
prefix-averaged models stacked on a leading flat walk-major axis — the
exact model order the batched utility evaluator consumes
(`core/shapley_batched.gtg_shapley_streaming`).
"""
from __future__ import annotations

from functools import partial
from typing import Any

import jax

from repro.kernels import default_interpret, pad_to
from repro.kernels.prefix_avg.kernel import prefix_avg_kernel
from repro.kernels.prefix_avg.ref import prefix_avg_ref

PyTree = Any


@partial(jax.jit, static_argnames=("use_kernel", "interpret", "block_d"))
def prefix_avg(stacked_tree: PyTree, perms: jax.Array, n_k: jax.Array, *,
               use_kernel: bool = True, interpret: bool | None = None,
               block_d: int = 2048) -> PyTree:
    """stacked_tree leaves (M, *s); perms (R, M) -> leaves (R*M, *s).

    `interpret=None` derives from the backend (compile natively on TPU,
    interpret elsewhere).
    """
    if interpret is None:
        interpret = default_interpret()
    r, m = perms.shape

    def one(leaf: jax.Array) -> jax.Array:
        flat = leaf.reshape(m, -1)
        d = flat.shape[1]
        if not use_kernel or d < block_d:
            out = prefix_avg_ref(flat, perms, n_k)
        else:
            padded = pad_to(flat, block_d)
            out = prefix_avg_kernel(padded, perms, n_k,
                                    block_d=block_d, interpret=interpret)
            out = out[:, :d]
        return out.reshape((r * m,) + leaf.shape[1:])

    return jax.tree.map(one, stacked_tree)

"""Pure-jnp oracle for the prefix_avg kernel.

The walk accumulation is an explicit left-to-right `lax.scan` (NOT a
cumsum, whose reduction tree XLA may reassociate): the per-position add
order is the bitwise contract shared with the Pallas kernel's j-loop and
relied on by the chunked streaming evaluator.  The gather lands directly
in walk-axis-leading (M, R, D) layout so the scan consumes contiguous
slices without transposing the big intermediate (the single output
transpose back to walk-major order is the only full copy).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def prefix_avg_ref(stacked: jax.Array, perms: jax.Array,
                   n_k: jax.Array) -> jax.Array:
    """stacked (M, D) x perms (R, M) x n_k (M,) -> (R*M, D) prefix models
    in f32 accumulation; row r*M + j averages the prefix perms[r, :j+1]."""
    r, m = perms.shape
    perms_t = perms.T                                     # (M, R)
    scale = jnp.take(n_k, perms_t).astype(jnp.float32)    # (M, R)
    ncum = jnp.cumsum(scale, axis=0)                      # (M, R)
    rows = jnp.take(stacked, perms_t,
                    axis=0).astype(jnp.float32)           # (M, R, D)

    def step(acc, x):
        g, s, n = x                                       # (R, D), (R,), (R,)
        acc = acc + s[:, None] * g
        return acc, acc / n[:, None]

    _, out = jax.lax.scan(
        step, jnp.zeros((r, stacked.shape[1]), jnp.float32),
        (rows, scale, ncum))                              # out (M, R, D)
    return out.swapaxes(0, 1).reshape(r * m, -1).astype(stacked.dtype)

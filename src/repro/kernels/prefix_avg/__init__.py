from repro.kernels.prefix_avg.ops import prefix_avg
from repro.kernels.prefix_avg.ref import prefix_avg_ref

__all__ = ["prefix_avg", "prefix_avg_ref"]

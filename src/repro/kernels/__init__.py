# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.
import jax
import jax.numpy as jnp


def pad_to(x, mult: int):
    """Zero-pad the last axis of a (M, D) matrix view up to a multiple of
    the kernel tile (shared by the ops wrappers; padding is sliced off
    after the kernel runs)."""
    pad = (-x.shape[-1]) % mult
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)))
    return x


def default_interpret() -> bool:
    """Backend-derived default for the kernels' `interpret` knob.

    Pallas TPU kernels must compile natively on TPU (interpret mode there
    would silently fall back to a slow emulation); everywhere else the
    interpreter IS the only way to run them.  ops wrappers resolve
    `interpret=None` through this at trace time.
    """
    return jax.default_backend() != "tpu"

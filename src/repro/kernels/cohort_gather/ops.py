"""Public wrapper: pytree-aware sparse cohort gather, dense or sharded.

Two regimes behind one call:

  * dense (`axis_name=None`) — the whole (N, ...) stack is local.  Big
    leaves route to the Pallas kernel on TPU (`use_kernel=None` resolves
    from the backend: the interpreter adds pure overhead to a copy, and
    `jnp.take` IS the bitwise reference, so off-TPU the ref is used);
    small leaves always take the ref, mirroring `prefix_avg`.

  * client-sharded (`axis_name="clients"`) — `arr` is this shard's
    (N/devices, ...) block inside a `shard_map` body and `ids` is the
    global replicated (M,) cohort.  Each shard gathers its local hits
    (clamped take + validity mask) and the rows are combined with a
    `psum` over the client axis.  Exactly one shard contributes each
    row, so the sum is exact — and float leaves are bit-exact too,
    because they are summed as same-width unsigned ints (bitcast, mask,
    psum, bitcast back), sidestepping float-add edge cases (-0.0, NaN
    payloads) that could break the sharded==dense bitwise contract.

Both regimes return bit-identical results to `jnp.take(arr, ids, 0)` on
the equivalent dense stack; the engines rely on that (DESIGN.md §16).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.kernels import default_interpret, pad_to
from repro.kernels.cohort_gather.kernel import BLOCK_D, cohort_gather_kernel
from repro.kernels.cohort_gather.ref import cohort_gather_ref

PyTree = Any


def _cross_shard_take(arr: jax.Array, ids: jax.Array,
                      axis_name: str) -> jax.Array:
    """Gather global rows `ids` out of this shard's local block of a
    client-axis-sharded (N, ...) stack; call inside a shard_map body."""
    n_local = arr.shape[0]
    lo = jax.lax.axis_index(axis_name) * n_local
    loc = ids - lo
    valid = (loc >= 0) & (loc < n_local)
    rows = jnp.take(arr, jnp.clip(loc, 0, n_local - 1), axis=0)
    mask = valid.reshape((-1,) + (1,) * (arr.ndim - 1))
    if jnp.issubdtype(arr.dtype, jnp.floating):
        # sum the bits, not the floats: integer adds of one-hot nonzero
        # contributions are exact, so sharded == dense stays bitwise
        uint = jnp.dtype(f"uint{arr.dtype.itemsize * 8}")
        bits = jax.lax.bitcast_convert_type(rows, uint)
        bits = jnp.where(mask, bits, jnp.zeros_like(bits))
        summed = jax.lax.psum(bits, axis_name)
        return jax.lax.bitcast_convert_type(summed, arr.dtype)
    rows = jnp.where(mask, rows, jnp.zeros_like(rows))
    return jax.lax.psum(rows, axis_name)


def cohort_take(arr: jax.Array, ids: jax.Array, *,
                axis_name: Optional[str] = None,
                use_kernel: Optional[bool] = None,
                interpret: Optional[bool] = None,
                block_d: int = BLOCK_D) -> jax.Array:
    """Gather rows `ids` (M,) from `arr` (N, ...) -> (M, ...).

    With `axis_name` set, `arr` is the local (N/devices, ...) shard of a
    client-axis-sharded stack (see `_cross_shard_take`); otherwise the
    dense single-device gather.  `use_kernel=None` resolves to
    TPU-only (a copy gains nothing from the Pallas interpreter);
    `interpret=None` derives from the backend like the other kernels.
    """
    if axis_name is not None:
        return _cross_shard_take(arr, ids, axis_name)
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"
    if interpret is None:
        interpret = default_interpret()
    m = ids.shape[0]
    flat = arr.reshape(arr.shape[0], -1)
    d = flat.shape[1]
    if not use_kernel or d < block_d:
        out = cohort_gather_ref(flat, ids)
    else:
        padded = pad_to(flat, block_d)
        out = cohort_gather_kernel(padded, ids, block_d=block_d,
                                   interpret=interpret)
        out = out[:, :d]
    return out.reshape((m,) + arr.shape[1:])


def cohort_gather(tree: PyTree, ids: jax.Array, *,
                  axis_name: Optional[str] = None,
                  use_kernel: Optional[bool] = None,
                  interpret: Optional[bool] = None,
                  block_d: int = BLOCK_D) -> PyTree:
    """Pytree version: every (N, ...) leaf gathered to (M, ...)."""
    take = partial(cohort_take, ids=ids, axis_name=axis_name,
                   use_kernel=use_kernel, interpret=interpret,
                   block_d=block_d)
    return jax.tree.map(lambda leaf: take(leaf), tree)

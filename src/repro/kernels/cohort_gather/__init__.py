"""Sparse cohort gather: selected-client rows out of (sharded) stacks.

The selection engines produce a global (M,) id vector each round; this
package turns it into the cohort's rows without materialising anything
O(N) beyond the (sharded) client stacks themselves:

  * `kernel.py`  — Pallas TPU gather with scalar-prefetched cohort ids
                   (the ids live in SMEM and drive the input BlockSpec's
                   index_map, so each output row's DMA fetches exactly
                   one table row);
  * `ref.py`     — the jnp oracle (`jnp.take`), the bitwise contract;
  * `ops.py`     — the public wrapper: pytree-aware single-device path
                   (kernel on TPU, ref elsewhere) plus the cross-shard
                   masked-gather + psum path for client-axis-sharded
                   stacks (DESIGN.md §16).
"""
from repro.kernels.cohort_gather.ops import cohort_gather, cohort_take

__all__ = ["cohort_gather", "cohort_take"]

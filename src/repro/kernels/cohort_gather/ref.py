"""Pure-jnp oracle for the cohort_gather kernel.

A gather copies bits — no arithmetic, no accumulation order — so the
kernel, this reference, and the engines' historical `jnp.take` are all
bitwise-identical by construction.  That is the contract that lets the
sharded engines route their cohort gathers through `ops.cohort_take`
without perturbing the dense parity oracles.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def cohort_gather_ref(table: jax.Array, ids: jax.Array) -> jax.Array:
    """table (N, D) x ids (M,) -> (M, D): `out[i] = table[ids[i]]`."""
    return jnp.take(table, ids, axis=0)

"""Pallas TPU kernel: cohort row gather driven by scalar-prefetched ids.

`out[i] = table[ids[i]]` for a (N, D) table and (M,) int ids.  A dense
`jnp.take` is a fine gather on small tables, but it gives XLA no hint
that only M ≪ N rows are live; here the cohort ids are scalar-prefetched
into SMEM and consumed by the *input BlockSpec's index_map*, so the DMA
pipeline fetches exactly one (1, BLOCK_D) tile of the table per output
row — the kernel body is a pure VMEM copy and the table never leaves HBM
beyond the M selected rows.

Grid: (M, D // BLOCK_D).  Program (i, j) copies block j of row ids[i].
The index_map receives the prefetched ids ref as a trailing argument
(PrefetchScalarGridSpec contract, same as `prefix_avg`); block indices
are in block units, and with a block shape of (1, BLOCK_D) the row-block
index IS the row id.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK_D = 2048  # lane-dim tile; multiple of 128 (MXU) and 8*128 (VREG)


def _gather_kernel(ids_ref, table_ref, out_ref):
    # ids: (M,) in SMEM; table_ref: the (1, BLOCK_D) tile of row ids[i]
    # (the index_map did the gather); out_ref: the matching output tile
    del ids_ref
    out_ref[...] = table_ref[...]


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def cohort_gather_kernel(table: jax.Array, ids: jax.Array, *,
                         block_d: int = BLOCK_D,
                         interpret: bool = False) -> jax.Array:
    """table (N, D) x ids (M,) int -> (M, D) gathered rows.

    D % block_d == 0 (callers pad; see ops.py).  Ids must be in [0, N).
    """
    n, d = table.shape
    (m,) = ids.shape
    assert d % block_d == 0, (d, block_d)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(m, d // block_d),
        in_specs=[
            # data-dependent row fetch: block row index = the cohort id
            pl.BlockSpec((1, block_d), lambda i, j, ids: (ids[i], j)),
        ],
        out_specs=pl.BlockSpec((1, block_d), lambda i, j, ids: (i, j)),
    )
    return pl.pallas_call(
        _gather_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, d), table.dtype),
        interpret=interpret,
    )(ids.astype(jnp.int32), table)

"""Synthetic stand-ins for MNIST / FashionMNIST / CIFAR10.

The evaluation container is offline, so the paper's public datasets are not
available.  We generate statistically-matched classification tasks — same
input shapes, 10 classes, a train/val/test split mirroring the paper's
5000/5000 server split — built from per-class anisotropic Gaussian clusters
with inter-class overlap controlled by `difficulty`.  All of the paper's
*relative* phenomena (heterogeneity sensitivity, straggler noise, privacy
noise) are preserved because they are properties of the FL pipeline, not of
the image statistics.  Absolute accuracies differ from the paper; see
EXPERIMENTS.md.
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np

SHAPES = {
    "mnist": (784,),
    "fmnist": (784,),
    "cifar10": (32, 32, 3),
}
N_CLASSES = 10


class SynthDataset(NamedTuple):
    name: str
    x_train: np.ndarray
    y_train: np.ndarray
    x_val: np.ndarray      # held at the server (utility evaluation)
    y_val: np.ndarray
    x_test: np.ndarray
    y_test: np.ndarray

    @property
    def input_shape(self):
        return self.x_train.shape[1:]


def make_dataset(name: str = "mnist", *, n_train: int = 12000, n_val: int = 1000,
                 n_test: int = 1000, difficulty: float = 1.0,
                 seed: int = 0) -> SynthDataset:
    """Class-clustered Gaussian images.  Higher `difficulty` => more overlap."""
    if name not in SHAPES:
        raise ValueError(f"unknown dataset {name!r}; options {sorted(SHAPES)}")
    shape = SHAPES[name]
    dim = int(np.prod(shape))
    rng = np.random.default_rng(seed)

    # class prototypes: sparse localized "strokes" so an MLP/CNN can learn them
    protos = np.zeros((N_CLASSES, dim), np.float32)
    for c in range(N_CLASSES):
        support = rng.choice(dim, size=max(dim // 8, 8), replace=False)
        protos[c, support] = rng.normal(1.5, 0.5, size=support.size)

    def sample(n, rng):
        y = rng.integers(0, N_CLASSES, size=n)
        noise = rng.normal(0.0, 0.6 * difficulty, size=(n, dim)).astype(np.float32)
        x = protos[y] + noise
        # per-sample random brightness/shift, mimicking image nuisances
        x += rng.normal(0.0, 0.2, size=(n, 1)).astype(np.float32)
        return x.reshape((n,) + shape).astype(np.float32), y.astype(np.int32)

    x_tr, y_tr = sample(n_train, rng)
    x_va, y_va = sample(n_val, rng)
    x_te, y_te = sample(n_test, rng)
    return SynthDataset(name, x_tr, y_tr, x_va, y_va, x_te, y_te)

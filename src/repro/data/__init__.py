from repro.data.synth import SynthDataset, make_dataset

__all__ = ["SynthDataset", "make_dataset"]

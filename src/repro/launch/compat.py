"""Version compatibility shims for the moving jax mesh/sharding APIs.

The ambient-mesh context manager has been renamed twice upstream
(`jax.sharding.use_mesh` -> `jax.sharding.set_mesh` -> `jax.set_mesh`), and
older releases (<= 0.4.x, as shipped in this container) have none of them —
there the `Mesh` object itself is the context manager.  Likewise older
`jax.jit` rejects bare `PartitionSpec`s in `in_shardings`/`out_shardings`;
they must be wrapped into `NamedSharding`s by hand.

Everything mesh-scoped in this repo goes through these two helpers so the
code runs unchanged across jax versions.
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

PyTree = Any


def set_mesh(mesh):
    """Context manager installing `mesh` as the ambient mesh.

    Resolution order: `jax.set_mesh` -> `jax.sharding.set_mesh` ->
    `jax.sharding.use_mesh` -> legacy `with mesh:` (the Mesh object is its
    own context manager on jax <= 0.4.x).
    """
    for mod in (jax, jax.sharding):
        for name in ("set_mesh", "use_mesh"):
            fn = getattr(mod, name, None)
            if fn is not None:
                return fn(mesh)
    return mesh


def cost_analysis_of(compiled) -> dict:
    """Normalised `cost_analysis()` of an AOT-compiled executable: a dict
    with whatever of `flops` / `bytes_accessed` the backend reports (keys
    absent when unavailable).  The raw API varies across jax versions/
    backends (list-of-dicts on some, missing keys on others); everything
    reading compiled costs goes through here."""
    out: dict = {}
    try:
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0]
        for key, name in (("flops", "flops"),
                          ("bytes accessed", "bytes_accessed")):
            v = cost.get(key)
            if v is not None and v == v:
                out[name] = float(v)
    except Exception:
        pass
    return out


def memory_stats_of(compiled):
    """Normalised `memory_analysis()` of an AOT-compiled executable: byte
    counts plus a derived `peak_bytes` = temp + argument + output −
    aliased, or None when the backend/version exposes no analysis (some
    CPU builds)."""
    try:
        mem = compiled.memory_analysis()
        sizes = {}
        for name in ("temp", "argument", "output", "alias",
                     "generated_code"):
            v = getattr(mem, f"{name}_size_in_bytes", None)
            if v is not None:
                sizes[f"{name}_bytes"] = int(v)
        if not sizes:
            return None
        peak = (sizes.get("temp_bytes", 0) + sizes.get("argument_bytes", 0)
                + sizes.get("output_bytes", 0) - sizes.get("alias_bytes", 0))
        sizes["peak_bytes"] = max(int(peak), 0)
        return sizes
    except Exception:
        return None


def aot_compile(jitted, *args, **kwargs):
    """`jitted.lower(*args).compile()`, None on failure.  Array arguments
    are reduced to their avals first, so the probe works on donated/
    deleted buffers and never touches data."""
    import jax as _jax

    def aval(a):
        if hasattr(a, "shape") and hasattr(a, "dtype"):
            return _jax.ShapeDtypeStruct(a.shape, a.dtype,
                                         sharding=getattr(a, "sharding",
                                                          None))
        return a

    try:
        args = _jax.tree.map(aval, args)
        kwargs = _jax.tree.map(aval, kwargs)
        return jitted.lower(*args, **kwargs).compile()
    except Exception:
        return None


def compiled_flops(jitted, *args, **kwargs) -> float:
    """Best-effort compiled-cost probe: the flops `jitted` would execute
    for these args, NaN when unavailable.  Costs a fresh lower+compile —
    callers gate it behind an explicit stats flag (or use the cached
    cost cards in repro.telemetry.profile)."""
    compiled = aot_compile(jitted, *args, **kwargs)
    if compiled is None:
        return float("nan")
    return cost_analysis_of(compiled).get("flops", float("nan"))


def compiled_memory_stats(jitted, *args, **kwargs):
    """Best-effort compiled peak-memory probe, mirroring `compiled_flops`:
    the XLA `memory_analysis()` byte counts (with derived `peak_bytes`),
    or None when unavailable.  Fresh lower+compile, like the flops probe."""
    compiled = aot_compile(jitted, *args, **kwargs)
    if compiled is None:
        return None
    return memory_stats_of(compiled)


def named_shardings(mesh, specs: PyTree) -> PyTree:
    """Normalise a pytree of PartitionSpec / None / Sharding leaves into
    `NamedSharding`s on `mesh` (None -> fully replicated).

    `jax.jit` on older versions only accepts concrete `Sharding`s; newer
    versions accept raw specs under an ambient mesh, where this wrapping is
    a harmless no-op semantically.
    """
    def conv(s):
        if s is None:
            s = P()
        if isinstance(s, jax.sharding.Sharding):
            return s
        return NamedSharding(mesh, s)

    return jax.tree.map(conv, specs,
                        is_leaf=lambda s: s is None or isinstance(s, P))

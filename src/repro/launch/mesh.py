"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — smoke tests must keep seeing 1 CPU device.

Production target: TPU v5e, 256 chips/pod.
  single-pod: (data=16, model=16)
  multi-pod:  (pod=2, data=16, model=16) = 512 chips

The federated engines use their own run meshes (DESIGN.md §12, §16):
  * `make_replica_mesh`  — 1-D ("replicas",): grid cells sharded whole,
    no collectives;
  * `make_run_mesh`      — 2-D ("replicas", "clients"): additionally
    shards ALL per-client state over `CLIENT_AXIS`, making per-device
    client memory O(N / clients_shards); the sparse cohort gather and
    the selector-state all-gather are the only cross-client collectives.
"""
from __future__ import annotations

import numpy as np

import jax

MODEL_AXIS = "model"


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices but only {len(devices)} present; "
            "the dry-run launcher must set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512 before any "
            "jax import")
    return jax.sharding.Mesh(np.asarray(devices[:n]).reshape(shape), axes)


def make_debug_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for CI-scale sharding tests (8 forced host devices)."""
    n = int(np.prod(shape))
    return jax.sharding.Mesh(np.asarray(jax.devices()[:n]).reshape(shape), axes)


REPLICA_AXIS = "replicas"
# Second grid-runner axis (DESIGN.md §16): per-client state — padded data
# stacks, n_valid, sigma, straggler tables, selector-state vectors — is
# sharded over it, so per-device client memory is O(N / clients_shards).
# Replicas stay embarrassingly parallel; only the cohort gather and the
# selector-state all-gather communicate over "clients".
CLIENT_AXIS = "clients"


def make_replica_mesh(n_replicas: int, *, max_devices=None):
    """1-D mesh for the grid runner's replica axis (repro.grid.shard).

    Uses the largest device count that divides `n_replicas` so every
    device holds whole replicas (replicas never communicate — no
    collectives, no padding).  Returns None when only one device would be
    used (the caller falls back to the unsharded vmap path)."""
    devices = jax.devices()
    limit = min(len(devices), max_devices or len(devices), n_replicas)
    n = max((d for d in range(1, limit + 1) if n_replicas % d == 0),
            default=1)
    if n <= 1:
        return None
    return jax.sharding.Mesh(np.asarray(devices[:n]), (REPLICA_AXIS,))


def make_run_mesh(n_replicas: int, clients_shards: int = 1, *,
                  max_devices=None):
    """Mesh for a (possibly replicated) scan run: 2-D (replicas, clients).

    `clients_shards` is the exact size of the client axis (the per-device
    client-state divisor the caller asked for); the replica axis then
    takes the largest divisor of `n_replicas` that fits the remaining
    devices, mirroring `make_replica_mesh` (whole replicas per device, no
    replica collectives).  With `clients_shards <= 1` this IS
    `make_replica_mesh` — the 1-D path, or None for the plain vmap.
    """
    if clients_shards <= 1:
        return make_replica_mesh(n_replicas, max_devices=max_devices)
    devices = jax.devices()
    limit = min(len(devices), max_devices or len(devices))
    if clients_shards > limit:
        raise ValueError(
            f"clients_shards={clients_shards} needs that many devices but "
            f"only {limit} are available (force more with "
            "XLA_FLAGS=--xla_force_host_platform_device_count=N)")
    r_limit = min(limit // clients_shards, n_replicas)
    r = max((d for d in range(1, r_limit + 1) if n_replicas % d == 0),
            default=1)
    grid = np.asarray(devices[: r * clients_shards]).reshape(
        r, clients_shards)
    return jax.sharding.Mesh(grid, (REPLICA_AXIS, CLIENT_AXIS))


def batch_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a != MODEL_AXIS)

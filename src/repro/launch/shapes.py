"""Assigned input shapes and ShapeDtypeStruct stand-ins for the dry-run.

No device allocation ever happens here — everything is jax.ShapeDtypeStruct
(weak-type-correct, shardable), including the decode caches (via
jax.eval_shape over init_cache).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.lm import model as M
from repro.models.lm.config import ArchConfig


class InputShape(NamedTuple):
    name: str
    seq_len: int
    global_batch: int
    kind: str        # train | prefill | decode


SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(cfg: ArchConfig, shape: InputShape) -> tuple[bool, str]:
    """long_500k requires a sub-quadratic decode path (DESIGN.md §5)."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "pure full-attention arch: no sub-quadratic 500k decode"
    return True, ""


def pad_vocab(cfg: ArchConfig, multiple: int = 16) -> ArchConfig:
    """Megatron-style vocab padding so the lm head shards over `model`."""
    v = cfg.vocab
    pad = (-v) % multiple
    return dataclasses.replace(cfg, vocab=v + pad) if pad else cfg


def batch_struct(cfg: ArchConfig, shape: InputShape) -> dict:
    """ShapeDtypeStruct batch for train/prefill kinds."""
    B, S = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.dtype)
    batch = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    if cfg.frontend == "vision":
        batch["patches"] = jax.ShapeDtypeStruct(
            (B, cfg.n_frontend_tokens, cfg.d_model), dt)
    if cfg.frontend == "audio":
        batch["frames"] = jax.ShapeDtypeStruct(
            (B, cfg.n_frontend_tokens, cfg.d_model), dt)
    return batch


def decode_structs(cfg: ArchConfig, shape: InputShape) -> tuple[dict, dict]:
    """(cache, batch) ShapeDtypeStructs for a decode step."""
    B, S = shape.global_batch, shape.seq_len
    cache = jax.eval_shape(lambda: M.init_cache(cfg, B, S))
    batch = {"token": jax.ShapeDtypeStruct((B,), jnp.int32)}
    return cache, batch


def params_struct(cfg: ArchConfig) -> dict:
    return jax.eval_shape(lambda: M.init_params(cfg, jax.random.key(0)))


def input_specs(cfg: ArchConfig, shape_name: str) -> dict:
    """All abstract inputs for the step function of this (arch, shape)."""
    shape = SHAPES[shape_name]
    if shape.kind == "train":
        return {"batch": batch_struct(cfg, shape)}
    if shape.kind == "prefill":
        return {"batch": batch_struct(cfg, shape)}
    cache, batch = decode_structs(cfg, shape)
    return {"cache": cache, "batch": batch}

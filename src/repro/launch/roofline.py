"""Roofline analysis from compiled dry-run artifacts.

TPU v5e hardware constants (per chip):
    peak bf16 compute   197 TFLOP/s
    HBM bandwidth       819 GB/s
    ICI per link        ~50 GB/s

Three terms per (arch x shape x mesh):
    compute    = FLOPs_per_device / 197e12
    memory     = bytes_per_device / 819e9
    collective = collective_traffic_per_device / 50e9

Methodology notes:
  * ``compiled.cost_analysis()`` runs on the post-SPMD per-device module, so
    its flops/bytes are already per-device.
  * XLA's HloCostAnalysis counts a while-loop body ONCE, ignoring the trip
    count — a scanned L-layer model would under-report by ~L.  We therefore
    ASSEMBLE the roofline from two python-unrolled compiles with 1 and 2
    layers (scan_layers=False):
        layer_cost    = cost(L=2) - cost(L=1)
        embed_head    = cost(L=1) - layer_cost
        total         = embed_head + n_layers * layer_cost
    (whisper's encoder scales with the same trick: both 1/2-layer models
    carry one/two encoder layers, and encoder_layers == n_layers.)
  * Collective traffic: parse the per-device HLO text, sum result-shape
    bytes of all-reduce/all-gather/reduce-scatter/all-to-all/
    collective-permute ops (all-reduce weighted 2x for the ring's
    reduce-scatter + all-gather phases).
"""
from __future__ import annotations

import dataclasses
import re

import jax
import numpy as np

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _line_result_bytes(lhs: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(lhs):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes_from_text(hlo: str) -> dict:
    """Per-collective-kind result bytes summed over the per-device module.

    NOTE: ops inside while bodies are counted once (see module docstring) —
    use the assembled numbers for scanned models.
    """
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo.splitlines():
        if "=" not in line:
            continue
        _, _, rhs = line.partition("=")
        rhs = rhs.strip()
        # op token appears right before '(' e.g. "bf16[128]{0} all-reduce(..."
        m = re.search(r"([\w-]+)\(", rhs)
        if not m:
            continue
        op = m.group(1)
        if op.endswith("-done"):
            continue  # async pair: bytes already counted at the -start op
        base = op[:-6] if op.endswith("-start") else op
        if base in _COLLECTIVES:
            out[base] += _line_result_bytes(rhs[: m.start()])
            counts[base] += 1
    total = sum(out.values()) + out["all-reduce"]  # all-reduce counts 2x
    return {"by_kind": out, "counts": counts, "weighted_total": total}


def _cost_of(fn, args, in_s, out_s) -> dict:
    lowered = jax.jit(fn, in_shardings=in_s, out_shardings=out_s).lower(*args)
    compiled = lowered.compile()
    cost = compiled.cost_analysis()
    coll = collective_bytes_from_text(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "collective_bytes": float(coll["weighted_total"]),
        "collective_by_kind": coll["by_kind"],
    }


def assembled_roofline(cfg, shape, mesh) -> dict:
    """Per-device FLOPs/bytes/collective totals via 1/2-layer differencing."""
    from repro.launch.dryrun import build_step  # circular-safe at call time

    def cost_with_layers(n: int) -> dict:
        enc = min(cfg.encoder_layers, n) if cfg.encoder_layers else 0
        c = dataclasses.replace(cfg, n_layers=n, encoder_layers=enc,
                                scan_layers=False, remat=False)
        fn, args, in_s, out_s = build_step(c, shape, mesh)
        return _cost_of(fn, args, in_s, out_s)

    c1 = cost_with_layers(1)
    c2 = cost_with_layers(2)
    L = cfg.n_layers

    def assemble(key):
        layer = max(c2[key] - c1[key], 0.0)
        stem = max(c1[key] - layer, 0.0)
        return stem + L * layer, layer, stem

    flops, flops_layer, flops_stem = assemble("flops")
    bytes_, bytes_layer, bytes_stem = assemble("bytes")
    coll, coll_layer, coll_stem = assemble("collective_bytes")
    return {
        "per_device_flops": flops,
        "per_device_bytes": bytes_,
        "per_device_collective_bytes": coll,
        "per_layer": {"flops": flops_layer, "bytes": bytes_layer,
                      "collective_bytes": coll_layer},
        "stem": {"flops": flops_stem, "bytes": bytes_stem,
                 "collective_bytes": coll_stem},
        "note": "remat disabled in assembly; training remat adds ~1 fwd of "
                "recompute per layer (see EXPERIMENTS.md)",
    }


def model_flops(cfg, shape) -> float:
    """6*N*D (train) / 2*N*D (inference) with N = active non-embedding params.

    Enc-dec (whisper): the encoder's params only see n_frontend_tokens
    frames, not the decoder's seq_len tokens — counted separately so the
    useful-FLOP ratio stays meaningful.
    """
    from repro.models.lm.config import (
        _attn_params, _ffn_params, active_param_count,
    )
    n = active_param_count(cfg) - cfg.vocab * cfg.d_model  # drop embed gather
    mult = {"train": 6.0, "prefill": 2.0, "decode": 2.0}[shape.kind]
    dec_tokens = shape.global_batch * (
        shape.seq_len if shape.kind != "decode" else 1)

    if not cfg.encoder_layers:
        return mult * n * dec_tokens

    enc_layer = 2 * cfg.d_model + _attn_params(cfg) + _ffn_params(cfg)
    n_enc = cfg.encoder_layers * enc_layer + cfg.d_model
    n_dec = n - n_enc
    enc_tokens = shape.global_batch * cfg.n_frontend_tokens
    # decode reuses the prefilled encoder output: encoder cost amortised away
    enc_mult = 0.0 if shape.kind == "decode" else mult
    return mult * n_dec * dec_tokens + enc_mult * n_enc * enc_tokens


def roofline_report(cfg, shape, rec: dict, *, n_devices: int) -> dict:
    asm = rec["assembled"]
    compute_t = asm["per_device_flops"] / PEAK_FLOPS
    memory_t = asm["per_device_bytes"] / HBM_BW
    coll_t = asm["per_device_collective_bytes"] / ICI_BW
    terms = {"compute_s": compute_t, "memory_s": memory_t,
             "collective_s": coll_t}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    hlo_global = asm["per_device_flops"] * n_devices
    report = {
        **terms,
        "dominant": dominant,
        "model_flops_global": mf,
        "hlo_flops_global": hlo_global,
        "useful_flops_ratio": mf / hlo_global if hlo_global else 0.0,
        "step_time_lower_bound_s": max(terms.values()),
        "flops_util_at_bound": (
            asm["per_device_flops"] / PEAK_FLOPS / max(max(terms.values()), 1e-12)),
    }
    return report

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) combination.

MUST be run as its own process (``python -m repro.launch.dryrun``): the two
lines above execute before any other import so the forced 512 host devices
are locked in before jax initialises.  Never set that flag globally — smoke
tests and benches must keep seeing 1 device.

For each combination this produces:
  * compiled.memory_analysis()  -> per-device bytes (does the step fit HBM?)
  * compiled.cost_analysis()    -> HLO FLOPs / bytes (roofline §compute/§memory)
  * HLO-text collective parse   -> collective bytes   (roofline §collective)
plus an "assembled" per-layer x trip-count roofline (launch/roofline.py) since
XLA's HloCostAnalysis counts a scanned while-body once, not n_layers times.

Artifacts land in experiments/dryrun/<arch>__<shape>__<mesh>.json.
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.launch.compat import named_shardings, set_mesh
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import (
    assembled_roofline, collective_bytes_from_text, roofline_report,
)
from repro.launch.shapes import (
    SHAPES, batch_struct, decode_structs, pad_vocab, params_struct,
    shape_applicable,
)
from repro.launch.sharding import (
    batch_specs, cache_specs, launch_cfg, logits_spec, opt_specs, param_specs,
)
from repro.models.lm import model as M
from repro.optim import make_optimizer

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def build_step(cfg, shape, mesh):
    """Return (fn, example_args, in_shardings, out_shardings)."""
    from jax.sharding import PartitionSpec as P

    pshape = params_struct(cfg)
    pspecs = param_specs(cfg, mesh, pshape)

    if shape.kind == "train":
        opt_init, step = M.make_train_step(cfg)
        oshape = jax.eval_shape(opt_init, pshape)
        ospecs = opt_specs(cfg, pspecs)
        bstruct = batch_struct(cfg, shape)
        bspecs = batch_specs(cfg, mesh, bstruct)

        def fn(params, opt_state, batch):
            return step(params, opt_state, batch)

        args = (pshape, oshape, bstruct)
        in_s = (pspecs, ospecs, bspecs)
        out_s = (pspecs, ospecs, P())
        return fn, args, in_s, out_s

    if shape.kind == "prefill":
        bstruct = batch_struct(cfg, shape)
        bspecs = batch_specs(cfg, mesh, bstruct)
        cshape = jax.eval_shape(
            lambda: M.init_cache(cfg, shape.global_batch, shape.seq_len))
        cspecs = cache_specs(cfg, mesh, cshape)

        def fn(params, batch):
            return M.prefill_step(cfg, params, batch,
                                  cache_len=shape.seq_len)

        args = (pshape, bstruct)
        in_s = (pspecs, bspecs)
        out_s = (cspecs, logits_spec(cfg, mesh, shape.global_batch))
        return fn, args, in_s, out_s

    # decode
    cshape, bstruct = decode_structs(cfg, shape)
    cspecs = cache_specs(cfg, mesh, cshape)
    bspecs = batch_specs(cfg, mesh, bstruct)

    def fn(params, cache, batch):
        return M.decode_step(cfg, params, cache, batch)

    args = (pshape, cshape, bstruct)
    in_s = (pspecs, cspecs, bspecs)
    out_s = (cspecs, logits_spec(cfg, mesh, shape.global_batch))
    return fn, args, in_s, out_s


def run_one(arch: str, shape_name: str, multi_pod: bool,
            assemble: bool = True, save: bool = True,
            cfg_override=None) -> dict:
    shape = SHAPES[shape_name]
    base = cfg_override if cfg_override is not None else get_config(arch)
    applicable, why = shape_applicable(base, shape)
    mesh_name = "multi" if multi_pod else "single"
    tag = f"{base.name}__{shape_name}__{mesh_name}"
    if not applicable:
        rec = {"tag": tag, "status": "skipped", "reason": why}
        if save:
            _save(tag, rec)
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = pad_vocab(base)
    cfg = launch_cfg(cfg, mesh, shape)

    t0 = time.time()
    fn, args, in_s, out_s = build_step(cfg, shape, mesh)
    with set_mesh(mesh):
        lowered = jax.jit(fn, in_shardings=named_shardings(mesh, in_s),
                          out_shardings=named_shardings(mesh, out_s)
                          ).lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        coll = collective_bytes_from_text(compiled.as_text())

    rec = {
        "tag": tag,
        "status": "ok",
        "arch": base.name,
        "shape": shape_name,
        "mesh": list(mesh.devices.shape),
        "n_devices": int(mesh.devices.size),
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": _mem_dict(mem),
        "hlo_cost": {"flops": cost.get("flops", -1.0),
                     "bytes_accessed": cost.get("bytes accessed", -1.0)},
        "collective_bytes_toplevel": coll,
    }
    if assemble:
        with set_mesh(mesh):
            rec["assembled"] = assembled_roofline(cfg, shape, mesh)
        rec["roofline"] = roofline_report(cfg, shape, rec,
                                          n_devices=int(mesh.devices.size))
    if save:
        _save(tag, rec)
    return rec


def _mem_dict(mem) -> dict:
    out = {}
    for k in ("temp_size_in_bytes", "argument_size_in_bytes",
              "output_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        try:
            out[k] = int(getattr(mem, k))
        except Exception:
            pass
    return out


def _save(tag: str, rec: dict) -> None:
    os.makedirs(OUT_DIR, exist_ok=True)
    with open(os.path.join(OUT_DIR, tag + ".json"), "w") as f:
        json.dump(rec, f, indent=1)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all",
                    help="arch id or 'all'")
    ap.add_argument("--shape", default="all",
                    help=f"one of {sorted(SHAPES)} or 'all'")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--no-assemble", action="store_true")
    args = ap.parse_args()

    archs = ARCH_IDS if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                label = f"{arch} x {shape} x {'multi' if mp else 'single'}"
                try:
                    rec = run_one(arch, shape, mp, assemble=not args.no_assemble)
                    if rec["status"] == "ok":
                        mem_gb = rec["memory"].get("temp_size_in_bytes", 0) / 2**30
                        print(f"[ok]   {label}: compile={rec['compile_s']}s "
                              f"temp/device={mem_gb:.2f}GiB "
                              f"flops={rec['hlo_cost']['flops']:.3e}")
                    else:
                        print(f"[skip] {label}: {rec['reason']}")
                except Exception as e:  # noqa: BLE001
                    failures.append((label, repr(e)))
                    print(f"[FAIL] {label}: {e}")
                    traceback.print_exc()
    if failures:
        raise SystemExit(f"{len(failures)} dry-run failures")
    print("dry-run complete: all combinations lowered and compiled")


if __name__ == "__main__":
    main()

"""Partition rules: params / optimizer state / batches / caches -> PartitionSpec.

Conventions (DESIGN.md §7):
  * batch dims shard over ("pod","data") — when divisible;
  * heads / d_ff / experts / vocab shard over "model" — when divisible
    (e.g. hymba's 25 heads and <16 KV heads replicate instead);
  * fsdp archs additionally shard the d_model/d_ff dim of big matrices over
    "data" (GSPMD all-gathers them at use — classic FSDP traffic);
  * decode KV caches shard KV-heads over "model" when divisible, otherwise
    the cache *sequence* dim (distributed-softmax decode attention);
  * SSM params/states shard over heads only when ssm_heads % model == 0.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.lm.config import ArchConfig
from repro.optim.adamw import AdamWState
from repro.optim.sgd import SGDState

PyTree = Any


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


class Rules:
    def __init__(self, cfg: ArchConfig, mesh):
        self.cfg = cfg
        self.mesh = mesh
        self.m = mesh.shape["model"]
        # "dp" parallelism: the model axis joins the batch axes and no param
        # dim is model-sharded — right for small archs (tinyllama) and archs
        # whose head counts don't divide the axis (hymba's 25 heads)
        self.dp = getattr(cfg, "parallelism", "tp") == "dp"
        if self.dp:
            self.batch_axes = tuple(mesh.axis_names)
        else:
            self.batch_axes = tuple(a for a in mesh.axis_names if a != "model")
        self.n_batch = 1
        for a in self.batch_axes:
            self.n_batch *= mesh.shape[a]
        self.data = "data" if cfg.fsdp else None
        self.d_fsdp = mesh.shape["data"] if cfg.fsdp else 1

    # -- helpers ----------------------------------------------------------
    def model_if(self, dim: int):
        if self.dp:
            return None
        return "model" if dim % self.m == 0 else None

    def data_if(self, dim: int):
        return self.data if (self.data and dim % self.d_fsdp == 0) else None

    def batch_if(self, dim: int):
        if dim % self.n_batch == 0:
            return self.batch_axes if len(self.batch_axes) > 1 else self.batch_axes[0]
        if len(self.batch_axes) > 1 and dim % self.mesh.shape["data"] == 0:
            return "data"
        return None

    @property
    def ssm_ok(self) -> bool:
        return self.cfg.ssm_heads % self.m == 0 if self.cfg.has_ssm else False

    # -- parameter rules ----------------------------------------------------
    def param_spec(self, path: str, shape: tuple) -> P:
        cfg, leading = self.cfg, ()
        if path.startswith(("layers/", "enc_layers/")):
            leading = (None,)           # stacked layer axis
            shape = shape[1:]

        def spec(*dims):
            return P(*(leading + dims))

        name = path.split("/")[-1]
        parent = path.split("/")[-2] if "/" in path else ""

        if path == "embed/table":
            return P(None, self.model_if(shape[1]))
        if path == "head/w":
            return P(self.data_if(shape[0]), self.model_if(shape[1]))
        if name == "scale":            # all norm scales replicated
            return spec(*(None,) * len(shape))
        if parent in ("attn", "cross_attn"):
            if name == "wq":
                return spec(self.data_if(shape[0]), self.model_if(shape[1]), None)
            if name in ("wk", "wv"):
                return spec(self.data_if(shape[0]), self.model_if(shape[1]), None)
            if name == "wo":
                return spec(self.model_if(shape[0]), None, self.data_if(shape[2]))
        if parent == "ffn":
            if name in ("w_gate", "w_up"):
                return spec(self.data_if(shape[0]), self.model_if(shape[1]))
            if name == "w_down":
                return spec(self.model_if(shape[0]), self.data_if(shape[1]))
        if parent == "moe":
            if name == "router":
                return spec(None, None)
            if name in ("w_gate", "w_up"):   # (E, D, F)
                return spec(self.model_if(shape[0]), self.data_if(shape[1]), None)
            if name == "w_down":             # (E, F, D)
                return spec(self.model_if(shape[0]), self.data_if(shape[1]), None)
        if parent == "ssm":
            di_ax = "model" if self.ssm_ok else None
            if name in ("proj_z", "proj_x"):
                return spec(self.data_if(shape[0]), di_ax)
            if name == "proj_dt":
                return spec(self.data_if(shape[0]),
                            di_ax if shape[1] % self.m == 0 else None)
            if name == "proj_bc":
                return spec(self.data_if(shape[0]), None)
            if name == "conv_x":
                return spec(None, di_ax)
            if name == "conv_bc":
                return spec(None, None)
            if name == "out_proj":
                return spec(di_ax, self.data_if(shape[1]))
            # A_log / D_skip / dt_bias
            return spec(*(None,) * len(shape))
        # fallback: replicate
        return P(*((None,) * (len(leading) + len(shape))))


def param_specs(cfg: ArchConfig, mesh, params_shape: PyTree) -> PyTree:
    rules = Rules(cfg, mesh)

    def one(path, leaf):
        return rules.param_spec(_path_str(path), leaf.shape)

    return jax.tree_util.tree_map_with_path(one, params_shape)


def opt_specs(cfg: ArchConfig, pspecs: PyTree) -> PyTree:
    if cfg.optimizer == "sgd":
        return SGDState(momentum=pspecs)
    return AdamWState(mu=pspecs, nu=pspecs, step=P())


def batch_specs(cfg: ArchConfig, mesh, batch_shape: PyTree) -> PyTree:
    rules = Rules(cfg, mesh)

    def one(path, leaf):
        b = rules.batch_if(leaf.shape[0])
        return P(b, *((None,) * (len(leaf.shape) - 1)))

    return jax.tree_util.tree_map_with_path(one, batch_shape)


def cache_specs(cfg: ArchConfig, mesh, cache_shape: PyTree) -> PyTree:
    """Decode caches: leaves are (L, B, ...) except the `pos` scalar."""
    rules = Rules(cfg, mesh)

    def one(path, leaf):
        name = _path_str(path)
        if name == "pos":
            return P()
        b = rules.batch_if(leaf.shape[1])
        if name in ("k", "v", "cross_k", "cross_v"):
            L, B, C, Kh, hd = leaf.shape
            if Kh % rules.m == 0:
                return P(None, b, None, "model", None)
            if C % rules.m == 0:
                return P(None, b, "model", None, None)   # sequence-sharded
            return P(None, b, None, None, None)
        if name == "ssm_state":       # (L, B, H, P, N)
            h_ax = "model" if rules.ssm_ok else None
            return P(None, b, h_ax, None, None)
        if name in ("ssm_conv_x",):   # (L, B, k, di)
            di_ax = "model" if rules.ssm_ok else None
            return P(None, b, None, di_ax)
        if name == "ssm_conv_bc":
            return P(None, b, None, None)
        return P(*((None,) * len(leaf.shape)))

    return jax.tree_util.tree_map_with_path(one, cache_shape)


def logits_spec(cfg: ArchConfig, mesh, batch: int) -> P:
    """Decode-step logits (B, V): batch + vocab sharding when divisible."""
    rules = Rules(cfg, mesh)
    return P(rules.batch_if(batch), rules.model_if(cfg.vocab))


def to_named(mesh, specs: PyTree) -> PyTree:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda s: isinstance(s, P))


def launch_cfg(cfg: ArchConfig, mesh, shape=None) -> ArchConfig:
    """Arm the model's sharding-constraint hooks + MoE grouping for `mesh`."""
    import dataclasses
    rules = Rules(cfg, mesh)
    upd: dict = {
        "mesh_batch_axes": rules.batch_axes,
        "mesh_batch_sizes": tuple(mesh.shape[a] for a in rules.batch_axes),
        "mesh_model_axis": "" if rules.dp else "model",
        "mesh_model_size": 0 if rules.dp else rules.m,
    }
    if cfg.is_moe and shape is not None and cfg.moe_groups == 1:
        # default grouping: one dispatch group per data shard (an explicit
        # cfg.moe_groups override, e.g. from the §Perf hillclimb, wins)
        tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
        groups = rules.n_batch
        while groups > 1 and (tokens % groups or tokens // groups < 8):
            groups //= 2
        upd["moe_groups"] = max(groups, 1)
    return dataclasses.replace(cfg, **upd)

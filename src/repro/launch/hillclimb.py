import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimbing driver: named config variants per target pair.

Each variant re-runs the dry-run roofline for one (arch, shape) pair with a
config delta, so every hypothesis -> change -> before/after cycle is one
CLI invocation producing a JSON record under experiments/perf/.

    PYTHONPATH=src python -m repro.launch.hillclimb --target tinyllama_train
"""
import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.launch.dryrun import run_one  # noqa: E402

OUT = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                   "experiments", "perf")

# variant name -> (arch, shape, config overrides)
# Hypotheses are documented in EXPERIMENTS.md §Perf next to the measurements.
VARIANTS = {
    # ---- tinyllama-1.1b x train_4k (collective-bound, 22x compute) -------
    "tinyllama_train/v0_baseline": ("tinyllama_1_1b", "train_4k", {}),
    "tinyllama_train/v1_bf16_params": (
        "tinyllama_1_1b", "train_4k", {"param_dtype": "bfloat16"}),
    "tinyllama_train/v2_dp": (
        "tinyllama_1_1b", "train_4k", {"parallelism": "dp"}),
    "tinyllama_train/v3_dp_bf16": (
        "tinyllama_1_1b", "train_4k",
        {"parallelism": "dp", "param_dtype": "bfloat16"}),
    "tinyllama_train/v4_dp_chunk2048": (
        "tinyllama_1_1b", "train_4k",
        {"parallelism": "dp", "attn_chunk": 2048}),
    "tinyllama_train/v5_dp_chunk4096": (
        "tinyllama_1_1b", "train_4k",
        {"parallelism": "dp", "attn_chunk": 4096}),
    "tinyllama_train/v6_dp_chunk2048_noremat": (
        "tinyllama_1_1b", "train_4k",
        {"parallelism": "dp", "attn_chunk": 2048, "remat": False}),
    # ---- kimi-k2 x train_4k (most collective-bound absolute) -------------
    "kimi_train/v0_baseline": ("kimi_k2_1t_a32b", "train_4k", {}),
    "kimi_train/v1_bf16_params": (
        "kimi_k2_1t_a32b", "train_4k", {"param_dtype": "bfloat16"}),
    "kimi_train/v2_bf16_bigchunk": (
        "kimi_k2_1t_a32b", "train_4k",
        {"param_dtype": "bfloat16", "attn_chunk": 2048}),
    "kimi_train/v3_bf16_remat_attn": (
        "kimi_k2_1t_a32b", "train_4k",
        {"param_dtype": "bfloat16", "attn_remat": True}),
    "kimi_train/v4_remat_groups64": (
        "kimi_k2_1t_a32b", "train_4k",
        {"param_dtype": "bfloat16", "attn_remat": True, "moe_groups": 64}),
    # ---- hymba-1.5b x train_4k (worst roofline fraction: memory) ---------
    "hymba_train/v0_baseline": ("hymba_1_5b", "train_4k", {}),
    "hymba_train/v1_dp": (
        "hymba_1_5b", "train_4k", {"parallelism": "dp"}),
    "hymba_train/v2_dp_attn_remat": (
        "hymba_1_5b", "train_4k",
        {"parallelism": "dp", "attn_remat": True}),
    "hymba_train/v3_dp_remat_chunk128": (
        "hymba_1_5b", "train_4k",
        {"parallelism": "dp", "attn_remat": True, "ssm_chunk": 128}),
    "hymba_train/v4_dp_remat_bf16": (
        "hymba_1_5b", "train_4k",
        {"parallelism": "dp", "attn_remat": True,
         "param_dtype": "bfloat16"}),
    "hymba_train/v5_dp_remat_chunk64": (
        "hymba_1_5b", "train_4k",
        {"parallelism": "dp", "attn_remat": True, "ssm_chunk": 64}),
    "hymba_train/v6_dp_remat_c128_attnchunk256": (
        "hymba_1_5b", "train_4k",
        {"parallelism": "dp", "attn_remat": True, "ssm_chunk": 128,
         "attn_chunk": 256}),
}


def run_variant(name: str) -> dict:
    arch, shape, overrides = VARIANTS[name]
    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    rec = run_one(arch, shape, multi_pod=False, assemble=True, save=False,
                  cfg_override=cfg)
    rec["variant"] = name
    rec["overrides"] = overrides
    os.makedirs(OUT, exist_ok=True)
    fname = name.replace("/", "__") + ".json"
    with open(os.path.join(OUT, fname), "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def _summ(rec: dict) -> str:
    r = rec["roofline"]
    mem = rec["memory"].get("temp_size_in_bytes", 0) / 2 ** 30
    return (f"compute={r['compute_s']:.3f}s memory={r['memory_s']:.3f}s "
            f"collective={r['collective_s']:.3f}s dom={r['dominant']} "
            f"util={r['useful_flops_ratio']:.2f} temp={mem:.1f}GiB")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--target", default=None,
                    help="prefix filter, e.g. tinyllama_train")
    ap.add_argument("--variant", default=None, help="exact variant name")
    args = ap.parse_args()
    names = [args.variant] if args.variant else [
        n for n in VARIANTS if args.target is None or
        n.startswith(args.target)]
    for name in names:
        try:
            rec = run_variant(name)
            print(f"[{name}] {_summ(rec)}")
        except Exception as e:  # noqa: BLE001
            print(f"[{name}] FAIL: {e}")
            raise


if __name__ == "__main__":
    main()

"""Training launcher: federated (GreedyFed) or plain data-parallel LM training.

    PYTHONPATH=src python -m repro.launch.train --mode federated \
        --dataset mnist --selector greedyfed --rounds 50
    PYTHONPATH=src python -m repro.launch.train --mode lm --arch tinyllama_1_1b \
        --steps 100 --d-model 256 --layers 4

On real hardware the LM mode runs under make_production_mesh(); on this CPU
container it runs a reduced config on one device (same code path, mesh of 1).
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np


def run_federated_mode(args) -> None:
    from repro.federated.client import ClientConfig
    from repro.federated.server import FLConfig, run_federated

    cfg = FLConfig(
        dataset=args.dataset, selector=args.selector,
        n_clients=args.clients, m=args.select, rounds=args.rounds,
        dirichlet_alpha=args.alpha, straggler_frac=args.stragglers,
        privacy_sigma=args.sigma, seed=args.seed,
        n_train=args.n_train, n_val=args.n_val, n_test=args.n_test,
        eval_every=max(args.rounds // 10, 1),
        client=ClientConfig(epochs=args.epochs,
                            batches_per_epoch=args.batches,
                            batch_size=args.batch_size),
    )
    res = run_federated(cfg)
    print("round,test_acc")
    for rnd, acc in res.test_acc:
        print(f"{rnd},{acc:.4f}")
    print(f"# final={res.final_acc:.4f} shapley_evals={res.shapley_evals} "
          f"wall={res.wall_time_s:.1f}s")
    if args.checkpoint:
        from repro.checkpoint.ckpt import save_server_state
        save_server_state(args.checkpoint, params=res.params,
                          sv=res.sv_final, counts=res.selection_counts,
                          round_idx=cfg.rounds, seed=cfg.seed)
        print(f"# checkpoint -> {args.checkpoint}")


def run_lm_mode(args) -> None:
    from repro.configs import get_config
    from repro.models.lm import model as M

    cfg = get_config(args.arch)
    if args.layers or args.d_model:  # reduced local run
        cfg = dataclasses.replace(
            cfg.reduced(n_layers=args.layers or 2,
                        d_model=args.d_model or 256),
            vocab=args.vocab, dtype="float32")
    key = jax.random.key(args.seed)
    params = M.init_params(cfg, key)
    opt_init, step = M.make_train_step(cfg)
    opt = opt_init(params)
    step = jax.jit(step)

    def synth_batch(k):
        b = {"tokens": jax.random.randint(k, (args.batch_size, args.seq), 0,
                                          cfg.vocab)}
        if cfg.frontend == "vision":
            b["patches"] = jax.random.normal(
                k, (args.batch_size, cfg.n_frontend_tokens, cfg.d_model))
        if cfg.frontend == "audio":
            b["frames"] = jax.random.normal(
                k, (args.batch_size, max(cfg.n_frontend_tokens, 8), cfg.d_model))
        return b

    print("step,loss,tok_per_s")
    t0 = time.time()
    for i in range(args.steps):
        key, k = jax.random.split(key)
        params, opt, metrics = step(params, opt, synth_batch(k))
        if i % max(args.steps // 20, 1) == 0 or i == args.steps - 1:
            dt = time.time() - t0
            tps = (i + 1) * args.batch_size * args.seq / max(dt, 1e-9)
            print(f"{i},{float(metrics['loss']):.4f},{tps:.0f}")
    assert np.isfinite(float(metrics["loss"]))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=["federated", "lm"], default="federated")
    # federated
    ap.add_argument("--dataset", default="mnist")
    ap.add_argument("--selector", default="greedyfed")
    ap.add_argument("--clients", type=int, default=30)
    ap.add_argument("--select", type=int, default=3)
    ap.add_argument("--rounds", type=int, default=50)
    ap.add_argument("--alpha", type=float, default=1e-4)
    ap.add_argument("--stragglers", type=float, default=0.0)
    ap.add_argument("--sigma", type=float, default=0.0)
    ap.add_argument("--epochs", type=int, default=5)
    ap.add_argument("--batches", type=int, default=5)
    ap.add_argument("--n-train", type=int, default=6000)
    ap.add_argument("--n-val", type=int, default=500)
    ap.add_argument("--n-test", type=int, default=1000)
    ap.add_argument("--checkpoint", default=None)
    # lm
    ap.add_argument("--arch", default="tinyllama_1_1b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--layers", type=int, default=0)
    ap.add_argument("--d-model", type=int, default=0)
    ap.add_argument("--vocab", type=int, default=2048)
    # shared
    ap.add_argument("--batch-size", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.mode == "federated":
        run_federated_mode(args)
    else:
        run_lm_mode(args)


if __name__ == "__main__":
    main()

from repro.checkpoint.ckpt import save_pytree, load_pytree, save_server_state, load_server_state

__all__ = ["save_pytree", "load_pytree", "save_server_state", "load_server_state"]

"""Checkpointing: pytree <-> npz with a structure manifest.

Round-resumable FL server state = (model params, valuation state, round idx,
rng key).  No orbax offline, so we serialise leaves to .npz and the treedef
to a JSON path-spec; load reconstructs and validates structure.
"""
from __future__ import annotations

import json
import os
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any
_SEP = "/"


def _flatten_with_paths(tree: PyTree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save_pytree(path: str, tree: PyTree) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten_with_paths(tree)
    treedef = jax.tree.structure(tree)
    np.savez(path if path.endswith(".npz") else path + ".npz", **flat)
    with open(_manifest_path(path), "w") as f:
        json.dump({"treedef": str(treedef), "keys": sorted(flat)}, f)


def _manifest_path(path: str) -> str:
    base = path[:-4] if path.endswith(".npz") else path
    return base + ".manifest.json"


def load_pytree(path: str, like: PyTree) -> PyTree:
    """Load into the structure of `like` (shape/dtype validated)."""
    npz = np.load(path if path.endswith(".npz") else path + ".npz")
    flat_like = _flatten_with_paths(like)
    if sorted(npz.files) != sorted(flat_like):
        raise ValueError(
            f"checkpoint structure mismatch: {sorted(npz.files)[:5]}... vs "
            f"{sorted(flat_like)[:5]}...")
    leaves_like, treedef = jax.tree.flatten(like)
    paths = [p for p, _ in jax.tree_util.tree_flatten_with_path(like)[0]]
    keys = [_SEP.join(str(getattr(q, "key", getattr(q, "idx", q))) for q in p)
            for p in paths]
    new_leaves = []
    for key, ref in zip(keys, leaves_like):
        arr = npz[key]
        if arr.shape != ref.shape:
            raise ValueError(f"shape mismatch at {key}: {arr.shape} vs {ref.shape}")
        new_leaves.append(jnp.asarray(arr, dtype=ref.dtype))
    return jax.tree.unflatten(treedef, new_leaves)


def _is_prng_key(x) -> bool:
    dtype = getattr(x, "dtype", None)
    return dtype is not None and jax.dtypes.issubdtype(dtype,
                                                       jax.dtypes.prng_key)


def encode_prng_keys(tree: PyTree) -> PyTree:
    """Replace typed PRNG-key leaves by their uint32 key data (npz-able)."""
    return jax.tree.map(
        lambda x: jax.random.key_data(x) if _is_prng_key(x) else x, tree)


def decode_prng_keys(tree: PyTree, like: PyTree) -> PyTree:
    """Re-wrap key data back into typed keys wherever `like` holds one."""
    return jax.tree.map(
        lambda x, l: jax.random.wrap_key_data(jnp.asarray(x))
        if _is_prng_key(l) else x, tree, like)


def save_carry(path: str, carry: PyTree, *, telemetry=None) -> None:
    """Checkpoint a scan-segment carry (params + selector state + typed
    rng key) — `save_pytree` with the key leaves made serialisable.
    With a telemetry sink, emits a `checkpoint_save` event carrying the
    path, on-disk bytes, and write seconds."""
    import time

    t0 = time.perf_counter()
    save_pytree(path, encode_prng_keys(carry))
    if telemetry is not None:
        full = path if path.endswith(".npz") else path + ".npz"
        telemetry.emit("checkpoint_save", path=full,
                       nbytes=os.path.getsize(full),
                       seconds=time.perf_counter() - t0)


def load_carry(path: str, like: PyTree, *, telemetry=None) -> PyTree:
    """Inverse of `save_carry`: bit-exact roundtrip including typed keys."""
    data = load_pytree(path, encode_prng_keys(like))
    if telemetry is not None:
        telemetry.emit("checkpoint_load",
                       path=path if path.endswith(".npz") else path + ".npz")
    return decode_prng_keys(data, like)


def save_server_state(path: str, *, params: PyTree, sv: np.ndarray,
                      counts: np.ndarray, round_idx: int, seed: int) -> None:
    save_pytree(path, {"params": params})
    meta = {"round": int(round_idx), "seed": int(seed)}
    np.savez(path[:-4] + ".meta.npz" if path.endswith(".npz") else path + ".meta.npz",
             sv=np.asarray(sv), counts=np.asarray(counts))
    with open((path[:-4] if path.endswith(".npz") else path) + ".meta.json", "w") as f:
        json.dump(meta, f)


def load_server_state(path: str, params_like: PyTree) -> dict:
    params = load_pytree(path, {"params": params_like})["params"]
    base = path[:-4] if path.endswith(".npz") else path
    meta_arr = np.load(base + ".meta.npz")
    with open(base + ".meta.json") as f:
        meta = json.load(f)
    return {"params": params, "sv": meta_arr["sv"], "counts": meta_arr["counts"],
            "round": meta["round"], "seed": meta["seed"]}

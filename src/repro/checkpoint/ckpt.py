"""Checkpointing: pytree <-> npz with a structure manifest.

Round-resumable FL server state = (model params, valuation state, round idx,
rng key).  No orbax offline, so we serialise leaves to .npz and the treedef
to a JSON path-spec; load reconstructs and validates structure.

Integrity (DESIGN.md §19): writes are atomic (tmp + fsync + rename, so a
kill mid-write leaves either the previous checkpoint or none), the manifest
carries a sha256 digest per leaf, and `load_pytree` raises
`CheckpointCorruptError` on any unreadable / truncated / digest-mismatched
file — `repro.grid.segments` catches it and falls back to the previous
segment boundary.  A *missing* checkpoint is NOT corruption
(FileNotFoundError propagates; resume treats it as "start from scratch"),
and a *structure* mismatch (caller handed the wrong `like`) stays a
ValueError — that is a programming error, not bit rot.
"""
from __future__ import annotations

import hashlib
import json
import os
import zipfile
import zlib
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any
_SEP = "/"


class CheckpointCorruptError(RuntimeError):
    """A checkpoint exists on disk but cannot be trusted: unreadable npz,
    missing/undecodable manifest, or a per-leaf sha256 mismatch."""


def _digest(arr: np.ndarray) -> str:
    h = hashlib.sha256()
    h.update(str(arr.dtype).encode())
    h.update(repr(arr.shape).encode())
    h.update(np.ascontiguousarray(arr).tobytes())
    return h.hexdigest()


def _atomic_write(path: str, writer: Callable) -> None:
    """Write via tmp + fsync + rename so readers never see a torn file."""
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        writer(f)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


def _flatten_with_paths(tree: PyTree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save_pytree(path: str, tree: PyTree) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten_with_paths(tree)
    treedef = jax.tree.structure(tree)
    npz_path = path if path.endswith(".npz") else path + ".npz"
    _atomic_write(npz_path, lambda f: np.savez(f, **flat))
    manifest = {"treedef": str(treedef), "keys": sorted(flat),
                "digests": {k: _digest(v) for k, v in flat.items()}}
    _atomic_write(_manifest_path(path),
                  lambda f: f.write(json.dumps(manifest).encode()))


def _manifest_path(path: str) -> str:
    base = path[:-4] if path.endswith(".npz") else path
    return base + ".manifest.json"


def _load_manifest(path: str) -> dict:
    """The manifest dict, or {} when absent (pre-§19 checkpoints carried
    no digests — tolerated, loads skip verification)."""
    try:
        with open(_manifest_path(path)) as f:
            return json.load(f)
    except FileNotFoundError:
        return {}
    except (OSError, ValueError) as e:
        raise CheckpointCorruptError(
            f"unreadable checkpoint manifest {_manifest_path(path)!r}: {e!r}"
        ) from e


def load_pytree(path: str, like: PyTree) -> PyTree:
    """Load into the structure of `like` (shape/dtype validated).

    Raises FileNotFoundError when the npz is absent (missing, not corrupt),
    CheckpointCorruptError when it is unreadable or fails digest
    verification, and ValueError on a structure mismatch with `like`."""
    npz_path = path if path.endswith(".npz") else path + ".npz"
    digests = _load_manifest(path).get("digests", {})
    try:
        npz = np.load(npz_path)
        files = sorted(npz.files)
    except FileNotFoundError:
        raise
    except (OSError, ValueError, KeyError, zipfile.BadZipFile,
            zlib.error) as e:
        raise CheckpointCorruptError(
            f"unreadable checkpoint {npz_path!r}: {e!r}") from e
    flat_like = _flatten_with_paths(like)
    if files != sorted(flat_like):
        raise ValueError(
            f"checkpoint structure mismatch: {files[:5]}... vs "
            f"{sorted(flat_like)[:5]}...")
    leaves_like, treedef = jax.tree.flatten(like)
    paths = [p for p, _ in jax.tree_util.tree_flatten_with_path(like)[0]]
    keys = [_SEP.join(str(getattr(q, "key", getattr(q, "idx", q))) for q in p)
            for p in paths]
    new_leaves = []
    for key, ref in zip(keys, leaves_like):
        try:
            arr = npz[key]
        except (OSError, ValueError, KeyError, zipfile.BadZipFile,
                zlib.error) as e:
            raise CheckpointCorruptError(
                f"unreadable leaf {key!r} in {npz_path!r}: {e!r}") from e
        if key in digests and _digest(arr) != digests[key]:
            raise CheckpointCorruptError(
                f"digest mismatch at leaf {key!r} in {npz_path!r}")
        if arr.shape != ref.shape:
            raise ValueError(f"shape mismatch at {key}: {arr.shape} vs {ref.shape}")
        new_leaves.append(jnp.asarray(arr, dtype=ref.dtype))
    return jax.tree.unflatten(treedef, new_leaves)


def _is_prng_key(x) -> bool:
    dtype = getattr(x, "dtype", None)
    return dtype is not None and jax.dtypes.issubdtype(dtype,
                                                       jax.dtypes.prng_key)


def encode_prng_keys(tree: PyTree) -> PyTree:
    """Replace typed PRNG-key leaves by their uint32 key data (npz-able)."""
    return jax.tree.map(
        lambda x: jax.random.key_data(x) if _is_prng_key(x) else x, tree)


def decode_prng_keys(tree: PyTree, like: PyTree) -> PyTree:
    """Re-wrap key data back into typed keys wherever `like` holds one."""
    return jax.tree.map(
        lambda x, l: jax.random.wrap_key_data(jnp.asarray(x))
        if _is_prng_key(l) else x, tree, like)


def save_carry(path: str, carry: PyTree, *, telemetry=None) -> None:
    """Checkpoint a scan-segment carry (params + selector state + typed
    rng key) — `save_pytree` with the key leaves made serialisable.
    With a telemetry sink, emits a `checkpoint_save` event carrying the
    path, on-disk bytes, and write seconds."""
    import time

    t0 = time.perf_counter()
    save_pytree(path, encode_prng_keys(carry))
    if telemetry is not None:
        full = path if path.endswith(".npz") else path + ".npz"
        telemetry.emit("checkpoint_save", path=full,
                       nbytes=os.path.getsize(full),
                       seconds=time.perf_counter() - t0)


def load_carry(path: str, like: PyTree, *, telemetry=None) -> PyTree:
    """Inverse of `save_carry`: bit-exact roundtrip including typed keys."""
    data = load_pytree(path, encode_prng_keys(like))
    if telemetry is not None:
        telemetry.emit("checkpoint_load",
                       path=path if path.endswith(".npz") else path + ".npz")
    return decode_prng_keys(data, like)


def save_server_state(path: str, *, params: PyTree, sv: np.ndarray,
                      counts: np.ndarray, round_idx: int, seed: int) -> None:
    save_pytree(path, {"params": params})
    meta = {"round": int(round_idx), "seed": int(seed)}
    np.savez(path[:-4] + ".meta.npz" if path.endswith(".npz") else path + ".meta.npz",
             sv=np.asarray(sv), counts=np.asarray(counts))
    with open((path[:-4] if path.endswith(".npz") else path) + ".meta.json", "w") as f:
        json.dump(meta, f)


def load_server_state(path: str, params_like: PyTree) -> dict:
    params = load_pytree(path, {"params": params_like})["params"]
    base = path[:-4] if path.endswith(".npz") else path
    meta_arr = np.load(base + ".meta.npz")
    with open(base + ".meta.json") as f:
        meta = json.load(f)
    return {"params": params, "sv": meta_arr["sv"], "counts": meta_arr["counts"],
            "round": meta["round"], "seed": meta["seed"]}

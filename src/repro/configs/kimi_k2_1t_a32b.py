"""kimi-k2-1t-a32b — trillion-parameter MoE (paper-table spec)
[arXiv:2501.kimi2].

61L d_model=7168 64H (GQA kv=8) per-expert d_ff=2048 vocab=163840,
MoE 384 experts top-8.  ~1.04T total params, ~32B active.

Distribution: FSDP over the data axis + expert parallelism over the model
axis; SGD-momentum optimizer (the paper's client optimizer — and the only
first-order state that fits 256 x 16 GB HBM at this scale; see
EXPERIMENTS.md §Dry-run for the memory ledger).
"""
from repro.models.lm.config import ArchConfig

CONFIG = ArchConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=2048,              # per-expert width
    vocab=163840,
    n_experts=384,
    top_k=8,
    capacity_factor=1.25,
    fsdp=True,
    optimizer="sgd",
    source="Kimi K2 [arXiv:2501.kimi2]",
)

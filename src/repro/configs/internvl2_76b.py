"""internvl2-76b — InternViT + InternLM2 VLM [arXiv:2404.16821].

Backbone (this config): 80L d_model=8192 64H (GQA kv=8) d_ff=28672
vocab=128256.  The InternViT vision encoder + MLP projector is a STUB per
the assignment carve-out: input_specs() supplies 256 precomputed patch
embeddings of width d_model which replace the first 256 token positions.
"""
from repro.models.lm.config import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-76b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab=128256,
    frontend="vision",
    n_frontend_tokens=256,
    fsdp=True,
    optimizer="adamw",
    source="InternVL2 [arXiv:2404.16821]",
)

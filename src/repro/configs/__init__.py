"""Assigned-architecture registry: ``--arch <id>`` resolves here.

Each module defines CONFIG (exact assigned spec, source cited) — plus the
paper's own task models (mnist_mlp / fmnist_mlp / cifar_cnn) for the FL
experiments.
"""
from __future__ import annotations

import importlib

from repro.models.lm.config import ArchConfig

ARCH_IDS = [
    "mamba2_370m",
    "h2o_danube_3_4b",
    "chatglm3_6b",
    "kimi_k2_1t_a32b",
    "qwen3_moe_30b_a3b",
    "internvl2_76b",
    "hymba_1_5b",
    "mistral_nemo_12b",
    "whisper_medium",
    "tinyllama_1_1b",
]

# CLI ids use dashes; module names use underscores
def _norm(name: str) -> str:
    return name.replace("-", "_").replace(".", "_")


# §Perf-winning overrides (EXPERIMENTS.md hillclimb log).  Baselines stay the
# papers' literal specs; `get_config(name, tuned=True)` applies these.
TUNED_OVERRIDES = {
    "tinyllama_1_1b": {"parallelism": "dp"},                      # 3.5x
    "hymba_1_5b": {"parallelism": "dp", "attn_remat": True,       # 36x
                   "ssm_chunk": 64},
    "kimi_k2_1t_a32b": {"param_dtype": "bfloat16",                # -6% mem;
                        "attn_remat": True},                      # bf16 wins on TPU
}


def get_config(name: str, *, tuned: bool = False) -> ArchConfig:
    import dataclasses
    mod = importlib.import_module(f"repro.configs.{_norm(name)}")
    cfg = mod.CONFIG
    if tuned:
        over = TUNED_OVERRIDES.get(_norm(name))
        if over:
            cfg = dataclasses.replace(cfg, **over)
    return cfg


def list_configs() -> list[str]:
    return list(ARCH_IDS)

"""mistral-nemo-12b — 128k-context dense model
[hf:mistralai/Mistral-Nemo-Base-2407].

40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072, head_dim 128.
Full attention (no SWA in Nemo) => long_500k decode is skipped per the
sub-quadratic rule (DESIGN.md §5).
"""
from repro.models.lm.config import ArchConfig

CONFIG = ArchConfig(
    name="mistral-nemo-12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab=131072,
    rope_theta=1e6,          # long-context rope base
    fsdp=True,
    optimizer="adamw",
    source="Mistral-Nemo [hf:mistralai/Mistral-Nemo-Base-2407]",
)

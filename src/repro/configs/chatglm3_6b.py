"""chatglm3-6b — 2D-RoPE (rotary on half the head dim), extreme GQA kv=2
[arXiv:2406.12793].

28L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=65024.
"""
from repro.models.lm.config import ArchConfig

CONFIG = ArchConfig(
    name="chatglm3-6b",
    family="dense",
    n_layers=28,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    head_dim=128,
    d_ff=13696,
    vocab=65024,
    rope_frac=0.5,          # chatglm applies rotary to half the dims ("2d")
    optimizer="adamw",
    source="ChatGLM [arXiv:2406.12793]",
)

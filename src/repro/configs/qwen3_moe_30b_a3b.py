"""qwen3-moe-30b-a3b — 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B].

48L d_model=2048 32H (GQA kv=4) per-expert d_ff=768 vocab=151936.
"""
from repro.models.lm.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    head_dim=128,
    d_ff=768,               # per-expert width
    vocab=151936,
    n_experts=128,
    top_k=8,
    capacity_factor=1.25,
    fsdp=True,
    optimizer="adamw",
    source="Qwen3-30B-A3B [hf:Qwen/Qwen3-30B-A3B]",
)

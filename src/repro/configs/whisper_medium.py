"""whisper-medium — encoder-decoder ASR backbone [arXiv:2212.04356].

24L (encoder) + 24L (decoder), d_model=1024 16H (kv=16, i.e. MHA)
d_ff=4096 vocab=51865, GELU MLP + LayerNorm, sinusoidal positions (no
RoPE: rope_frac=0).  The mel-spectrogram + conv feature extractor is a
STUB per the assignment carve-out: input_specs() supplies 1500 precomputed
frame embeddings consumed by the encoder.
"""
from repro.models.lm.config import ArchConfig

CONFIG = ArchConfig(
    name="whisper-medium",
    family="audio",
    n_layers=24,
    encoder_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab=51865,
    ffn_kind="gelu",
    norm_kind="layer",
    rope_frac=0.0,
    frontend="audio",
    n_frontend_tokens=1500,
    optimizer="adamw",
    source="Whisper [arXiv:2212.04356]",
)

"""mamba2-370m — SSD (state-space duality) [arXiv:2405.21060].

48L d_model=1024, attention-free, d_ff=0, vocab=50280, ssm_state=128.
d_inner = 2*1024 = 2048, P=64 => 32 SSD heads.
"""
from repro.models.lm.config import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab=50280,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=256,
    optimizer="adamw",
    source="SSD / Mamba2 [arXiv:2405.21060]",
)

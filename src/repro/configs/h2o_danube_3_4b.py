"""h2o-danube-3-4b — llama+mistral mix with sliding-window attention
[arXiv:2401.16818].

24L d_model=3840 32H (GQA kv=8) d_ff=10240 vocab=32000; head_dim 120.
SWA window 4096 (mistral-style local attention) => subquadratic decode,
so this dense arch DOES run long_500k (DESIGN.md §5).
"""
from repro.models.lm.config import ArchConfig

CONFIG = ArchConfig(
    name="h2o-danube-3-4b",
    family="dense",
    n_layers=24,
    d_model=3840,
    n_heads=32,
    n_kv_heads=8,
    head_dim=120,
    d_ff=10240,
    vocab=32000,
    window=4096,
    rope_theta=10000.0,
    optimizer="adamw",
    source="H2O-Danube 3 [arXiv:2401.16818]",
)

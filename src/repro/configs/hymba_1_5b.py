"""hymba-1.5b — parallel attention + mamba heads in every layer
[arXiv:2411.13676].

32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001, ssm_state=16.
Attention heads use a 1024-token sliding window (Hymba uses SWA in all but
three layers; we apply it uniformly — noted in DESIGN.md) => subquadratic,
runs long_500k.  25 heads are not divisible by the 16-way model axis, so
attention is replicated across `model` and parallelism comes from the FFN
and SSM d_inner (3200 = 16 x 200) — see launch/sharding.py.
"""
from repro.models.lm.config import ArchConfig

CONFIG = ArchConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab=32001,
    ssm_state=16,
    ssm_head_dim=64,
    ssm_expand=2,
    window=1024,
    optimizer="adamw",
    source="Hymba [arXiv:2411.13676]",
)

from repro.optim.sgd import SGDState, sgd_init, sgd_step
from repro.optim.adamw import AdamWState, adamw_init, adamw_step

__all__ = [
    "SGDState", "sgd_init", "sgd_step",
    "AdamWState", "adamw_init", "adamw_step",
    "make_optimizer",
]


def make_optimizer(name: str, **kw):
    """Return (init_fn, step_fn) pair closing over hyperparameters."""
    if name == "sgd":
        lr = kw.get("lr", 0.01)
        momentum = kw.get("momentum", 0.5)
        return (lambda p: sgd_init(p),
                lambda g, s, p: sgd_step(g, s, p, lr=lr, momentum=momentum))
    if name == "adamw":
        lr = kw.get("lr", 3e-4)
        return (lambda p: adamw_init(p),
                lambda g, s, p: adamw_step(g, s, p, lr=lr,
                                           b1=kw.get("b1", 0.9), b2=kw.get("b2", 0.95),
                                           eps=kw.get("eps", 1e-8),
                                           weight_decay=kw.get("weight_decay", 0.0)))
    raise ValueError(f"unknown optimizer {name!r}")

"""AdamW for the LM configs (SGD is too slow to be a realistic LM default)."""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any


class AdamWState(NamedTuple):
    mu: PyTree
    nu: PyTree
    step: jax.Array


def adamw_init(params: PyTree) -> AdamWState:
    zeros = lambda p: jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), p)
    return AdamWState(mu=zeros(params), nu=zeros(params),
                      step=jnp.zeros((), jnp.int32))


def adamw_step(grads: PyTree, state: AdamWState, params: PyTree, *,
               lr: float, b1: float = 0.9, b2: float = 0.95,
               eps: float = 1e-8, weight_decay: float = 0.0
               ) -> tuple[PyTree, AdamWState]:
    step = state.step + 1
    sf = step.astype(jnp.float32)
    c1 = 1.0 - b1 ** sf
    c2 = 1.0 - b2 ** sf

    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                      state.mu, grads)
    nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
                      state.nu, grads)

    def upd(p, m, v):
        u = (m / c1) / (jnp.sqrt(v / c2) + eps) + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

    new_p = jax.tree.map(upd, params, mu, nu)
    return new_p, AdamWState(mu=mu, nu=nu, step=step)

"""SGD with momentum — the paper's client optimizer (eta=0.01, gamma=0.5)."""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any


class SGDState(NamedTuple):
    momentum: PyTree


def sgd_init(params: PyTree) -> SGDState:
    return SGDState(momentum=jax.tree.map(jnp.zeros_like, params))


def sgd_step(grads: PyTree, state: SGDState, params: PyTree,
             *, lr: float, momentum: float = 0.0) -> tuple[PyTree, SGDState]:
    new_m = jax.tree.map(lambda m, g: momentum * m + g, state.momentum, grads)
    new_p = jax.tree.map(lambda p, m: p - lr * m, params, new_m)
    return new_p, SGDState(momentum=new_m)

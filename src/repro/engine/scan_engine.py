"""engine="scan": a whole federated run as ONE compiled program.

The batched engine (round_engine.RoundEngine) fused each round into a
single dispatch but left strategy logic on the host, so a T-round run
still pays T device→host→device syncs — selection reads the round's
Shapley values, so the chain cannot pipeline.  Here the device-resident
selector stack (repro.core.selection_jax) moves selection and valuation
into the trace and `make_run_scan` rolls the T rounds into one `lax.scan`:
the whole run — selection, straggler E_k gathers, local training, upload
codec, GTG-Shapley, ModelAverage, cumulative-SV updates, cadenced evals —
is a single dispatch (DESIGN.md §11).

This module is the host-side orchestration: it precomputes the run's
static tables (per-round epoch budgets, the Power-of-Choice candidate
schedule), invokes the cached executable, and rebuilds the usual FLResult
bookkeeping (byte accounting, virtual-clock replay, eval history) from
the scan's stacked outputs.

Parity contract: with deadline-derived or absent stragglers, an
`engine="scan"` run produces the same selections (bit-identical) and
final params (to jit-fusion tolerance) as `engine="batched"` at the same
seed — tests/test_engine.py pins greedyfed, fedavg, and power_of_choice.
With `straggler_frac > 0` the paper's random E_k draw cannot be replayed
on-device in the legacy stream order; the scan engine pre-draws a (T, N)
table instead (schedule.straggler_epochs_table) — same distribution,
different stream.
"""
from __future__ import annotations

import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.selection_jax import poc_d_schedule
from repro.engine.round_engine import RoundSpec, ScanSpec, jitted_run_scan
from repro.engine.schedule import (
    VirtualClock, deadline_epochs_table, eval_mask, round_duration_s,
    straggler_epochs_table,
)
from repro.federated.compression import codec_nbytes

PyTree = Any


def build_epochs_table(cfg, s) -> np.ndarray:
    """(T, N) int32 local-epoch budgets for every round of a scan run.

    At straggler_rev >= 1 the random-straggler table was already drawn by
    `setup_run` (same rng position, same values) and is shared with the
    loop/batched engines — all three are stream-identical.  The lazy draw
    below only serves the legacy straggler_rev=0 path."""
    e = cfg.client.epochs
    if s.clock is not None:
        return deadline_epochs_table(s.clock, cfg.schedule, cfg.rounds, e)
    if s.epochs_table is not None:
        return s.epochs_table
    if s.straggler_ids:
        return straggler_epochs_table(s.rng, cfg.rounds, cfg.n_clients,
                                      s.straggler_ids, e)
    return np.full((cfg.rounds, cfg.n_clients), e, np.int32)


def build_fault_table(cfg, s) -> np.ndarray:
    """(T, N) int32 fault codes for a scan run (§19); zeros when faults
    are off so the operand slot keeps one uniform signature per shape —
    the codes are dead operands in clean traces and get DCE'd."""
    if s.fault_table is not None:
        return np.asarray(s.fault_table, np.int32)
    return np.zeros((cfg.rounds, cfg.n_clients), np.int32)


def scan_operands(cfg, s) -> tuple:
    """The positional operands of a solo run's `jitted_run_scan` call,
    everything after the leading `params`: (xs, ..., sel_state, key).
    The single source of that call contract — `run_federated_scan` and
    `benchmarks/engine_bench._scan_steady_state` both build their calls
    from it, so an operand reorder cannot silently desynchronise them."""
    return (s.xs, s.ys, s.n_valid, jnp.asarray(s.sigma_k_all),
            s.x_val, s.y_val, s.x_test, s.y_test, jnp.asarray(s.fractions),
            jnp.asarray(build_epochs_table(cfg, s)),
            jnp.asarray(build_fault_table(cfg, s)),
            jnp.asarray(poc_d_schedule(s.sel_spec, cfg.rounds)),
            jnp.asarray(eval_mask(cfg.rounds, cfg.eval_every)),
            jnp.asarray(0, jnp.int32), s.sel_state, s.key)


def make_scan_spec(cfg, selector_specs: tuple, *, live_tap: bool = False,
                   client_axis: str = None) -> ScanSpec:
    """ScanSpec for an FLConfig; `selector_specs` may hold several
    strategies for a switch-dispatched mixed batch (superset semantics:
    SV is computed if ANY strategy needs it).  `live_tap` opts the trace
    into the in-scan telemetry callback (DESIGN.md §15); `client_axis`
    bakes the client-sharding collectives into the round trace
    (DESIGN.md §16 — set it iff the step runs inside the client-axis
    shard_map)."""
    needs_sv = any(sp.uses_shapley for sp in selector_specs)
    max_iters = cfg.shapley_max_iters or 50 * cfg.m
    rspec = RoundSpec(needs_sv=needs_sv, shapley_impl=cfg.shapley_impl,
                      shapley_eps=cfg.shapley_eps,
                      shapley_max_iters=max_iters,
                      sv_chunk=cfg.sv_chunk,
                      upload_codec=cfg.upload_codec,
                      client_axis=client_axis,
                      faults=cfg.faults, quarantine=cfg.quarantine,
                      quarantine_z=cfg.quarantine_z)
    # eval_every is NOT in the spec: the cadence is a (T,) bool operand
    # (schedule.eval_mask), so one executable serves every cadence
    return ScanSpec(round=rspec, selectors=tuple(selector_specs),
                    rounds=cfg.rounds, live_tap=live_tap)


def results_from_scan(cfg, s, out, *, wall_time_s: float, seed: int,
                      dispatches: int, uses_shapley: bool,
                      compile_time_s: float = 0.0):
    """Rebuild the host-side FLResult bookkeeping from a ScanRunOutput."""
    from repro.federated.server import FLConfig, FLResult  # cycle-free at call time
    import dataclasses

    sels = np.asarray(out.selections)
    epochs = np.asarray(out.epochs)
    selections = [row.astype(np.int64) for row in sels]

    # charge uploads at the ACTUAL granted-cohort size per round (dropout
    # strategies can grant fewer than m active clients), matching the
    # loop engine's per-selected-client accounting (replicated.py)
    codec_bytes = codec_nbytes(cfg.upload_codec, s.params)
    upload_bytes = codec_bytes * int(np.asarray(out.granted).sum())
    download_bytes = s.model_bytes * cfg.m * cfg.rounds

    vclock = VirtualClock() if s.clock is not None else None
    if vclock is not None:
        for t in range(cfg.rounds):
            vclock.advance(round_duration_s(s.clock, cfg.schedule,
                                            sels[t], epochs[t]))

    acc = np.asarray(out.test_acc)
    vloss = np.asarray(out.val_loss)
    emask = eval_mask(cfg.rounds, cfg.eval_every)
    # the in-scan eval-slot counter (SegmentCarry.eval_slot) must agree
    # with the host-side mask the curve is rebuilt from — a mismatch means
    # the replica ran a different cadence than this cell's config says
    # (e.g. a mis-stacked eval table under the replica vmap)
    n_evals = int(np.asarray(out.eval_count))
    if n_evals != int(emask.sum()):
        raise RuntimeError(
            f"eval-slot counter recorded {n_evals} in-scan evals but the "
            f"cell's eval mask (rounds={cfg.rounds}, "
            f"eval_every={cfg.eval_every}) expects {int(emask.sum())}")
    test_acc, val_loss_hist = [], []
    for t in np.flatnonzero(emask):
        test_acc.append((int(t) + 1, float(acc[t])))
        val_loss_hist.append((int(t) + 1, float(vloss[t])))

    total_evals = int(np.asarray(out.utility_evals).sum()) if uses_shapley else 0
    final_cfg = cfg if cfg.seed == seed else dataclasses.replace(cfg, seed=seed)
    return FLResult(
        config=final_cfg,
        test_acc=test_acc,
        val_loss=val_loss_hist,
        final_acc=test_acc[-1][1] if test_acc else float("nan"),
        sv_final=np.asarray(out.sel_state.valuation.sv),
        selection_counts=np.asarray(out.sel_state.valuation.counts),
        selections=selections,
        shapley_evals=total_evals,
        wall_time_s=wall_time_s,
        params=out.params,
        upload_bytes=upload_bytes,
        download_bytes=download_bytes,
        sim_time_s=vclock.now_s if vclock is not None else 0.0,
        dispatches=dispatches,
        compile_time_s=compile_time_s,
        execute_time_s=max(wall_time_s - compile_time_s, 0.0),
        quarantined_total=int(np.asarray(out.quarantined).sum()),
    )


def _sharded_scan_batch(cfg, s, mesh):
    """The 1-replica ReplicaBatch of a client-sharded solo run.

    The data stacks from `setup_run(..., client_mesh=mesh)` are already
    (N_pad, ...) arrays sharded over CLIENT_AXIS; they gain their leading
    replica axis through a jit with explicit out_shardings — a local
    per-shard reshape, never a gather.  Host-side operands (sigma, the
    epochs tables, the initial selector state) are zero-padded to N_pad;
    fractions stays the exact (N,) vector (replicated, read whole by
    selection).
    """
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.engine.round_engine import SegmentCarry
    from repro.grid.segments import ReplicaBatch
    from repro.grid.shard import CLIENT_AXIS, clients_padded
    from repro.engine.schedule import eval_mask as emask_fn

    n_pad = clients_padded(cfg.n_clients, cfg.clients_shards)

    def pad_rows(a, axis=0):
        a = np.asarray(a)
        widths = [(0, 0)] * a.ndim
        widths[axis] = (0, n_pad - a.shape[axis])
        return np.pad(a, widths)

    expand = jax.jit(lambda a: a[None], out_shardings=NamedSharding(
        mesh, P(None, CLIENT_AXIS)))

    def rep1(a):
        return jnp.asarray(a)[None]

    sel_state = jax.tree.map(
        lambda x: jnp.asarray(pad_rows(x))[None] if x.ndim >= 1
        else jnp.asarray(x)[None], s.sel_state)
    carry = SegmentCarry(
        params=jax.tree.map(rep1, s.params), sel_state=sel_state,
        key=jnp.asarray(s.key)[None],
        eval_slot=jnp.zeros((1,), jnp.int32))
    return ReplicaBatch(
        carry=carry,
        xs=expand(s.xs), ys=expand(s.ys), nv=expand(s.n_valid),
        sigma=jnp.asarray(pad_rows(s.sigma_k_all))[None],
        x_val=rep1(s.x_val), y_val=rep1(s.y_val),
        x_test=rep1(s.x_test), y_test=rep1(s.y_test),
        fractions=jnp.asarray(s.fractions, jnp.float32)[None],
        epochs_tables=jnp.asarray(
            pad_rows(build_epochs_table(cfg, s), axis=1))[None],
        fault_tables=jnp.asarray(
            pad_rows(build_fault_table(cfg, s), axis=1))[None],
        d_scheds=jnp.asarray(poc_d_schedule(s.sel_spec, cfg.rounds))[None],
        eval_masks=jnp.asarray(emask_fn(cfg.rounds, cfg.eval_every))[None],
        strategy_ids=jnp.zeros((1,), jnp.int32))


def _run_scan_sharded(cfg, s, spec, t_start, *, telemetry, ctimer):
    """Client-sharded solo run: the one scan dispatch goes through the
    shard_map segment step on a (1, clients_shards) run mesh; outputs are
    unpadded + replica-squeezed back into the dense run's exact shapes.
    Bit-identical to the dense scan at equal config (DESIGN.md §16)."""
    from repro.grid.segments import run_segments
    from repro.grid.shard import make_run_mesh, unpad_scan_output
    from repro.telemetry.profile import trace_capture

    spec_sel = s.sel_spec
    # deterministic rebuild of the mesh setup_run sharded the data on
    # (Mesh is hashable/comparable, so the step cache keys correctly)
    mesh = make_run_mesh(1, cfg.clients_shards)
    with ctimer:
        batch = _sharded_scan_batch(cfg, s, mesh)
    with trace_capture(telemetry, label="run_scan_client_sharded"):
        out_b, report = run_segments(s.model, cfg.client, spec, batch,
                                     mesh=mesh, telemetry=telemetry)
    out_b = unpad_scan_output(out_b, cfg.n_clients)
    out = jax.tree.map(lambda x: x[0], out_b)

    res = results_from_scan(cfg, s, out,
                            wall_time_s=time.perf_counter() - t_start,
                            seed=cfg.seed, dispatches=report.n_segments,
                            uses_shapley=spec_sel.uses_shapley,
                            compile_time_s=(ctimer.seconds
                                            + report.compile_time_s))
    if telemetry is not None:
        from repro.telemetry.metrics import emit_scan_rounds, run_end_payload
        telemetry.emit("compile", seconds=res.compile_time_s,
                       program="run_scan_client_sharded",
                       cost_card=report.cost_card)
        emit_scan_rounds(
            telemetry, out, uses_shapley=spec_sel.uses_shapley,
            codec_bytes=codec_nbytes(cfg.upload_codec, s.params),
            model_bytes=s.model_bytes,
            emask=eval_mask(cfg.rounds, cfg.eval_every))
        telemetry.emit("run_end", **run_end_payload(
            rounds=cfg.rounds, wall_time_s=res.wall_time_s,
            compile_time_s=res.compile_time_s, final_acc=res.final_acc,
            utility_evals=res.shapley_evals,
            upload_bytes=res.upload_bytes, download_bytes=res.download_bytes,
            sv_rounds=cfg.rounds if spec_sel.uses_shapley else 0,
            truncated_rounds=int(np.asarray(out.sv_truncated).sum())
            if spec_sel.uses_shapley else 0,
            dispatches=report.n_segments))
    return res


def run_federated_scan(cfg, s, t_start: float, *, telemetry=None,
                       ctimer=None):
    """Execute `cfg.rounds` federated rounds as one scan dispatch.

    `s` is the RunSetup from `server.setup_run` — the rng/key streams it
    consumed match the other engines, so the scan starts from identical
    partitions, params, and selector order.

    With `cfg.clients_shards > 1` the dispatch routes through the
    client-sharded shard_map path (`_run_scan_sharded`, DESIGN.md §16);
    results are bit-identical to the dense run.

    `telemetry=None` is the zero-cost default: no extra dispatches, no
    in-trace callbacks, bit-identical outputs.  With a sink attached the
    stacked ScanRunOutput is unrolled into per-round events after the
    dispatch (host-side, §15), and the compile event carries the scan
    executable's cost card (§17); `telemetry.live_tap` additionally
    selects the tap-carrying executable and routes its in-scan
    callbacks, and `telemetry.trace_dir` wraps the dispatch in a
    profiler capture window.
    """
    from repro.telemetry.trace import CompileTimer, live_sink, stage

    spec_sel = s.sel_spec
    live = bool(telemetry is not None and telemetry.live_tap)
    if ctimer is None:
        ctimer = CompileTimer()
    if cfg.clients_shards > 1:
        from repro.launch.mesh import CLIENT_AXIS
        spec = make_scan_spec(cfg, (spec_sel,), live_tap=live,
                              client_axis=CLIENT_AXIS)
        return _run_scan_sharded(cfg, s, spec, t_start,
                                 telemetry=telemetry, ctimer=ctimer)
    spec = make_scan_spec(cfg, (spec_sel,), live_tap=live)

    from repro.telemetry.profile import trace_capture

    operands = scan_operands(cfg, s)
    with ctimer, trace_capture(telemetry, label="run_scan") as capturing:
        run = jitted_run_scan(s.model, cfg.client, spec)
        with live_sink(telemetry if live else None), stage("scan"):
            out = run(s.params, *operands)
            if live or capturing is not None:
                # drain the in-scan debug callbacks before the sink
                # detaches — taps must land inside the run's stream —
                # and keep capture-window spans covering execution, not
                # just the dispatch enqueue
                jax.block_until_ready(out.params)

    res = results_from_scan(cfg, s, out,
                            wall_time_s=time.perf_counter() - t_start,
                            seed=cfg.seed, dispatches=1,
                            uses_shapley=spec_sel.uses_shapley,
                            compile_time_s=ctimer.seconds)
    if telemetry is not None:
        from repro.telemetry.metrics import emit_scan_rounds, run_end_payload
        from repro.telemetry.profile import cached_cost_card
        telemetry.emit("compile", seconds=ctimer.seconds, program="run_scan",
                       cost_card=cached_cost_card(run, s.params, *operands))
        emit_scan_rounds(
            telemetry, out, uses_shapley=spec_sel.uses_shapley,
            codec_bytes=codec_nbytes(cfg.upload_codec, s.params),
            model_bytes=s.model_bytes,
            emask=eval_mask(cfg.rounds, cfg.eval_every))
        telemetry.emit("run_end", **run_end_payload(
            rounds=cfg.rounds, wall_time_s=res.wall_time_s,
            compile_time_s=res.compile_time_s, final_acc=res.final_acc,
            utility_evals=res.shapley_evals,
            upload_bytes=res.upload_bytes, download_bytes=res.download_bytes,
            sv_rounds=cfg.rounds if spec_sel.uses_shapley else 0,
            truncated_rounds=int(np.asarray(out.sv_truncated).sum())
            if spec_sel.uses_shapley else 0,
            dispatches=1))
    return res

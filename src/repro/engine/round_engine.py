"""The fused round engine: one jitted program per communication round.

The legacy `run_federated` loop issues, per round, M `client_update`
dispatches + a GTG-Shapley dispatch + a `weighted_average` dispatch, each a
host->device round-trip XLA cannot fuse across.  `round_step` traces the
whole round — cohort gather, vmapped local training, upload codec, GTG-
Shapley, ModelAverage — into ONE compiled program with the server `params`
buffer donated, so at paper scale (N=300, T=400, 6 strategies x seeds) the
simulator stops being the bottleneck (DESIGN.md §6).

Numerical parity with the legacy loop is a hard invariant (it is the
oracle): same key-splitting, same op order per client, same Shapley calls.
`tests/test_engine.py` pins selections, final params, and byte accounting
against the loop for greedyfed / fedavg / power_of_choice.

`make_round_step` returns the *untraced* function so `replicated.py` can
vmap it over a seed axis before jitting — one compilation serves a whole
multi-seed benchmark table.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.aggregation import normalized_weights, weighted_average
from repro.core.shapley import gtg_shapley
from repro.engine.batch_client import cohort_update
from repro.federated.client import ClientConfig
from repro.federated.compression import codec_nbytes, codec_roundtrip
from repro.models.mlp_cnn import ClassifierModel

PyTree = Any


class RoundSpec(NamedTuple):
    """Static (hashable) round-execution config baked into the trace."""
    needs_sv: bool = False
    shapley_impl: str = "serial"   # "serial" (Alg. 2) | "batched" (§8)
    shapley_eps: float = 1e-4
    shapley_max_iters: int = 250
    upload_codec: str = "identity"


class RoundOutput(NamedTuple):
    params: PyTree             # w^{t+1}
    sv: jax.Array              # (M,) this round's GTG-SV (zeros if unused)
    utility_evals: jax.Array   # scalar int32
    sv_truncated: jax.Array    # bool: between-round truncation fired


def make_round_step(model: ClassifierModel, ccfg: ClientConfig,
                    spec: RoundSpec) -> Callable[..., RoundOutput]:
    """Build the traceable round function (jit/vmap applied by callers).

    Signature of the returned fn:
        (params, xs_all, ys_all, nv_all, sigma_all, x_val, y_val,
         sel, epochs_k, round_key) -> RoundOutput
    """

    def round_step(params, xs_all, ys_all, nv_all, sigma_all, x_val, y_val,
                   sel, epochs_k, round_key) -> RoundOutput:
        stacked, n_k_sel, sv_key = cohort_update(
            model, ccfg, params, xs_all, ys_all, nv_all, sigma_all,
            sel, epochs_k, round_key)

        if spec.upload_codec != "identity":
            stacked = jax.vmap(
                lambda u: codec_roundtrip(spec.upload_codec, u, params)
            )(stacked)

        m = sel.shape[0]
        sv = jnp.zeros((m,))
        evals = jnp.array(0, jnp.int32)
        truncated = jnp.array(False)
        if spec.needs_sv:
            def utility_fn(p):  # U(w) = -L(w; D_val), as in the loop engine
                return -model.loss(p, x_val, y_val)

            if spec.shapley_impl == "batched":
                from repro.core.shapley_batched import (
                    gtg_shapley_batched, make_batched_mlp_utility,
                )
                # the same helper the loop engine uses (works on traced
                # x_val/y_val), so loop and fused engines agree bitwise
                batched_utility_fn = make_batched_mlp_utility(
                    model, x_val, y_val)
                sv, stats = gtg_shapley_batched(
                    stacked, n_k_sel, params, utility_fn,
                    batched_utility_fn, sv_key, eps=spec.shapley_eps,
                    n_perms=spec.shapley_max_iters)
            else:
                sv, stats = gtg_shapley(
                    stacked, n_k_sel, params, utility_fn, sv_key,
                    eps=spec.shapley_eps, max_iters=spec.shapley_max_iters)
            evals = stats.utility_evals
            truncated = stats.truncated_round

        new_params = weighted_average(stacked, normalized_weights(n_k_sel))
        return RoundOutput(new_params, sv, evals, truncated)

    return round_step


@functools.lru_cache(maxsize=16)
def _jitted_round_step_cached(model, ccfg, spec, donate, vmapped):
    fn = make_round_step(model, ccfg, spec)
    if vmapped:
        fn = jax.vmap(fn)
    return jax.jit(fn, donate_argnums=donate)


def jitted_round_step(model: ClassifierModel, ccfg: ClientConfig,
                      spec: RoundSpec, *, vmapped: bool = False):
    """Process-wide (bounded) cache of compiled round steps.

    All key components are immutable NamedTuples (`make_classifier` is
    memoized, so the same dataset yields the same model object), which
    means every run of the same config — each seed of a benchmark table
    cell — reuses one trace and one executable instead of recompiling.
    The LRU bound keeps sweeps that build ad-hoc models per point from
    accumulating executables for the process lifetime.
    """
    # params are consumed and replaced every round: donate the buffer so
    # XLA updates in place (donation is a silent no-op we skip on CPU).
    donate = (0,) if jax.default_backend() in ("tpu", "gpu") else ()
    return _jitted_round_step_cached(model, ccfg, spec, donate, vmapped)


class RoundEngine:
    """Owns the compiled `round_step` plus the per-run constant operands.

    One instance per `run_federated` call: the full padded client stacks,
    privacy sigmas, and validation split are bound once; per round only
    (params, sel, epochs_k, key) cross the host boundary — a single
    dispatch, vs O(M) for the legacy loop.
    """

    def __init__(self, model: ClassifierModel, ccfg: ClientConfig,
                 spec: RoundSpec, xs_all, ys_all, nv_all, sigma_all,
                 x_val, y_val):
        self.spec = spec
        self._step = jitted_round_step(model, ccfg, spec)
        self._operands = (jnp.asarray(xs_all), jnp.asarray(ys_all),
                          jnp.asarray(nv_all), jnp.asarray(sigma_all),
                          jnp.asarray(x_val), jnp.asarray(y_val))

    def step(self, params: PyTree, sel, epochs_k, round_key) -> RoundOutput:
        """Execute one full communication round as one dispatch."""
        return self._step(params, *self._operands, jnp.asarray(sel),
                          jnp.asarray(epochs_k), round_key)

    def upload_nbytes_per_client(self, params: PyTree) -> int:
        """Wire bytes of one client upload under this spec's codec."""
        return codec_nbytes(self.spec.upload_codec, params)

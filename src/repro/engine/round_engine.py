"""The fused round engine: one jitted program per communication round.

The legacy `run_federated` loop issues, per round, M `client_update`
dispatches + a GTG-Shapley dispatch + a `weighted_average` dispatch, each a
host->device round-trip XLA cannot fuse across.  `round_step` traces the
whole round — cohort gather, vmapped local training, upload codec, GTG-
Shapley, ModelAverage — into ONE compiled program with the server `params`
buffer donated, so at paper scale (N=300, T=400, 6 strategies x seeds) the
simulator stops being the bottleneck (DESIGN.md §6).

Numerical parity with the legacy loop is a hard invariant (it is the
oracle): same key-splitting, same op order per client, same Shapley calls.
`tests/test_engine.py` pins selections, final params, and byte accounting
against the loop for greedyfed / fedavg / power_of_choice.

`make_round_step` returns the *untraced* function so `replicated.py` can
vmap it over a seed axis before jitting — one compilation serves a whole
multi-seed benchmark table.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.aggregation import normalized_weights, weighted_average
from repro.core.selection_jax import (
    DeviceSelectionContext, DeviceSelectorState, SelectorSpec,
    device_select_any, device_update_any, gather_client_state,
)
from repro.core.shapley import gtg_shapley
from repro.engine.batch_client import cohort_update
from repro.faults.quarantine import harden_cohort, masked_average
from repro.faults.spec import FaultSpec
from repro.kernels.cohort_gather import cohort_take
from repro.kernels.delta_codec import delta_codec_roundtrip
from repro.federated.client import ClientConfig, local_loss
from repro.federated.compression import codec_nbytes
from repro.models.mlp_cnn import ClassifierModel

PyTree = Any


SHAPLEY_IMPLS = ("streaming", "batched", "serial")


class RoundSpec(NamedTuple):
    """Static (hashable) round-execution config baked into the trace."""
    needs_sv: bool = False
    # "streaming" (§14 prefix walk, the default) | "batched" (§8 dense
    # oracle) | "serial" (Alg. 2 truncation — degrades under vmap: the
    # within-round lax.cond runs both branches, worst-case cost with none
    # of the savings)
    shapley_impl: str = "streaming"
    shapley_eps: float = 1e-4
    shapley_max_iters: int = 250
    # streaming SV only: prefix models materialised + evaluated per step,
    # rounded up to whole M-model walks; bounds peak SV memory at
    # O(max(sv_chunk, M) * D) for replica-sharded grids.  0 = auto (one
    # walk off-TPU, all R*M on TPU), < 0 forces the all-resident pass.
    # Numerics-invariant: every chunking is bit-identical.
    sv_chunk: int = 0
    upload_codec: str = "identity"
    # Client-axis sharding (DESIGN.md §16): mesh-axis name the (N, ...)
    # client stacks and per-client selector state are sharded over when
    # the step runs inside a shard_map body; None = dense single-device
    # stacks.  Sharded and dense traces are bit-identical by contract
    # (sparse gathers copy bits; selection runs on the gathered (N,)
    # state either way).
    client_axis: Optional[str] = None
    # Fault injection + quarantine (DESIGN.md §19).  `faults` is the
    # FaultSpec whose pre-drawn (T, N) code table the engines thread in
    # as a per-round operand; `quarantine` turns on the in-round screen
    # (finite-check + robust norm cutoff on the decoded deltas).  Both
    # are static: fault-free traces with `faults=None, quarantine=False`
    # contain zero hardening ops, and quarantine-on over a clean cohort
    # is bitwise identical to off (every mask where() is an identity).
    faults: Optional[FaultSpec] = None
    quarantine: bool = False
    quarantine_z: float = 8.0


class RoundOutput(NamedTuple):
    params: PyTree             # w^{t+1}
    sv: jax.Array              # (M,) this round's GTG-SV (zeros if unused)
    utility_evals: jax.Array   # scalar int32
    sv_truncated: jax.Array    # bool: between-round truncation fired
    ok: jax.Array              # (M,) bool: survived fault mask + screen
    quarantined: jax.Array     # () int32 quarantined cohort rows


def make_round_step(model: ClassifierModel, ccfg: ClientConfig,
                    spec: RoundSpec) -> Callable[..., RoundOutput]:
    """Build the traceable round function (jit/vmap applied by callers).

    Signature of the returned fn:
        (params, xs_all, ys_all, nv_all, sigma_all, x_val, y_val,
         sel, epochs_k, round_key, fault_codes) -> RoundOutput
    where fault_codes is the (M,) int32 gather of the fault table at the
    selected clients (zeros when faults are off — the operand keeps a
    uniform signature and is dead-code-eliminated from clean traces).
    """
    if spec.shapley_impl not in SHAPLEY_IMPLS:
        raise ValueError(f"unknown shapley_impl {spec.shapley_impl!r}; "
                         f"options: {SHAPLEY_IMPLS}")
    if spec.faults is not None:
        spec.faults.validate()
    hardened = spec.faults is not None or spec.quarantine

    from repro.telemetry.trace import named_stage

    def round_step(params, xs_all, ys_all, nv_all, sigma_all, x_val, y_val,
                   sel, epochs_k, round_key, fault_codes) -> RoundOutput:
        # named_stage scopes are pure HLO metadata (DESIGN.md §15): they
        # let a profile of the fused dispatch attribute time to
        # train/shapley/aggregate instead of one opaque program
        with named_stage("train"):
            stacked, n_k_sel, sv_key = cohort_update(
                model, ccfg, params, xs_all, ys_all, nv_all, sigma_all,
                sel, epochs_k, round_key, client_axis=spec.client_axis)

            if spec.upload_codec != "identity":
                # fused delta-codec roundtrip (DESIGN.md §18): one pass
                # over the stacked cohort per leaf — Pallas kernel on TPU,
                # rowwise fused ref elsewhere — replacing the old
                # per-client vmap of the per-leaf top_k/scatter chain
                with named_stage("codec"):
                    stacked = delta_codec_roundtrip(stacked, params,
                                                    spec.upload_codec)

        m = sel.shape[0]
        ok = jnp.ones((m,), bool)
        quarantined = jnp.zeros((), jnp.int32)
        n_k_sv = n_k_sel
        if hardened:
            # §19: inject the coded faults into the decoded cohort, run
            # the quarantine screen, and mask failures out of everything
            # downstream (aggregation weights, SV weights, byte ledger)
            with named_stage("quarantine"):
                h = harden_cohort(stacked, params, n_k_sel, fault_codes,
                                  faults=spec.faults,
                                  quarantine=spec.quarantine,
                                  z=spec.quarantine_z)
            stacked, ok, quarantined, n_k_sv = (h.stacked, h.ok,
                                                h.quarantined, h.n_k_sv)

        sv = jnp.zeros((m,))
        evals = jnp.array(0, jnp.int32)
        truncated = jnp.array(False)
        if spec.needs_sv:
            def utility_fn(p):  # U(w) = -L(w; D_val), as in the loop engine
                return -model.loss(p, x_val, y_val)

            with named_stage("shapley"):
                if spec.shapley_impl in ("batched", "streaming"):
                    from repro.core.shapley_batched import (
                        gtg_shapley_batched, gtg_shapley_streaming,
                        make_batched_mlp_utility,
                    )
                    # the same helper the loop engine uses (works on traced
                    # x_val/y_val), so loop and fused engines agree bitwise
                    batched_utility_fn = make_batched_mlp_utility(
                        model, x_val, y_val)
                    if spec.shapley_impl == "streaming":
                        sv, stats = gtg_shapley_streaming(
                            stacked, n_k_sv, params, utility_fn,
                            batched_utility_fn, sv_key,
                            eps=spec.shapley_eps,
                            n_perms=spec.shapley_max_iters,
                            sv_chunk=spec.sv_chunk)
                    else:
                        sv, stats = gtg_shapley_batched(
                            stacked, n_k_sv, params, utility_fn,
                            batched_utility_fn, sv_key,
                            eps=spec.shapley_eps,
                            n_perms=spec.shapley_max_iters)
                else:
                    sv, stats = gtg_shapley(
                        stacked, n_k_sv, params, utility_fn, sv_key,
                        eps=spec.shapley_eps,
                        max_iters=spec.shapley_max_iters)
                evals = stats.utility_evals
                truncated = stats.truncated_round
            if hardened:
                # quarantined rows entered the walk as w_prev at weight
                # 2^-100 (bitwise-absorbed, DESIGN.md §19): zero their SV
                # so the valuation update never credits them
                sv = jnp.where(ok, sv, jnp.zeros((), sv.dtype))

        with named_stage("aggregate"):
            if hardened:
                new_params = masked_average(stacked, h.n_k_agg, ok, params)
            else:
                new_params = weighted_average(stacked,
                                              normalized_weights(n_k_sel))
        return RoundOutput(new_params, sv, evals, truncated, ok, quarantined)

    return round_step


@functools.lru_cache(maxsize=16)
def _jitted_round_step_cached(model, ccfg, spec, donate, vmapped):
    fn = make_round_step(model, ccfg, spec)
    if vmapped:
        fn = jax.vmap(fn)
    return jax.jit(fn, donate_argnums=donate)


def jitted_round_step(model: ClassifierModel, ccfg: ClientConfig,
                      spec: RoundSpec, *, vmapped: bool = False):
    """Process-wide (bounded) cache of compiled round steps.

    All key components are immutable NamedTuples (`make_classifier` is
    memoized, so the same dataset yields the same model object), which
    means every run of the same config — each seed of a benchmark table
    cell — reuses one trace and one executable instead of recompiling.
    The LRU bound keeps sweeps that build ad-hoc models per point from
    accumulating executables for the process lifetime.
    """
    # params are consumed and replaced every round: donate the buffer so
    # XLA updates in place (donation is a silent no-op we skip on CPU).
    donate = (0,) if jax.default_backend() in ("tpu", "gpu") else ()
    return _jitted_round_step_cached(model, ccfg, spec, donate, vmapped)


class ScanSpec(NamedTuple):
    """Static config for the whole-run `lax.scan` program (DESIGN.md §11).

    `selectors` is a tuple of device SelectorSpecs: length 1 dispatches
    statically; longer tuples compile a `lax.switch` over strategies so one
    executable serves a mixed-strategy replica batch (all entries must
    share n_clients / m for shapes to agree).

    `rounds_per_segment` (DESIGN.md §12) sets the trip count of ONE
    compiled segment: 0 means the whole run (`rounds`) is a single scan;
    K > 0 compiles a K-round segment whose carry is surfaced to the host
    between dispatches so `repro.grid.segments` can checkpoint/resume.
    `rounds` stays the run's TOTAL length either way.

    The eval cadence is NOT part of the spec: evals are driven by the
    precomputed `(T,)` bool table from `engine.schedule.eval_mask`
    (DESIGN.md §13), passed as a scan operand — one executable serves
    every cadence, and under the replica vmap the stacked `(R, T)` rows
    give each replica its own per-cell cadence.

    `live_tap` (DESIGN.md §15) plants the opt-in telemetry callback
    (`repro.telemetry.trace.round_tap`) in the scan body so round metrics
    stream out WHILE the one-dispatch run executes.  Trace-affecting
    (separate cache entry) but bit-neutral; default False keeps the
    standard executables callback-free.
    """
    round: RoundSpec
    selectors: tuple            # tuple[SelectorSpec, ...]
    rounds: int                 # T: total rounds of the run
    rounds_per_segment: int = 0  # K: segment scan length (0 = whole run)
    live_tap: bool = False       # in-scan telemetry stream (§15)


class ScanRunOutput(NamedTuple):
    params: PyTree              # w^T
    sel_state: DeviceSelectorState
    selections: jax.Array       # (T, M) int32
    epochs: jax.Array           # (T, M) int32 E_k actually granted
    sv: jax.Array               # (T, M) per-round GTG-SV (zeros if unused)
    utility_evals: jax.Array    # (T,) int32
    sv_truncated: jax.Array     # (T,) bool
    test_acc: jax.Array         # (T,) NaN on non-eval rounds
    val_loss: jax.Array         # (T,) NaN on non-eval rounds
    granted: jax.Array          # (T,) int32 active (granted) cohort size
    quarantined: jax.Array      # (T,) int32 quarantined cohort rows (§19)
    eval_count: jax.Array       # () int32 evals THIS replica performed


class SegmentCarry(NamedTuple):
    """Everything a scan run threads between rounds — and therefore the
    exact state that crosses a segment boundary (DESIGN.md §12).  A
    checkpoint of this pytree (plus the global round index t0 of the next
    segment) is sufficient to resume a killed run bit-identically."""
    params: PyTree
    sel_state: DeviceSelectorState
    key: jax.Array              # typed PRNG key (per replica when vmapped)
    # per-replica eval-slot counter (DESIGN.md §13): how many eval slots
    # this replica has filled so far — under the replica vmap the shared
    # eval round runs for everyone, so the counter (not the round index)
    # is the replica's position in ITS own eval curve
    eval_slot: jax.Array        # () int32


class SegmentOutput(NamedTuple):
    """One segment's carry-out plus its stacked (K, ...) round outputs."""
    carry: SegmentCarry
    selections: jax.Array       # (K, M) int32
    epochs: jax.Array           # (K, M) int32
    sv: jax.Array               # (K, M)
    utility_evals: jax.Array    # (K,) int32
    sv_truncated: jax.Array     # (K,) bool
    test_acc: jax.Array         # (K,) NaN on non-eval rounds
    val_loss: jax.Array         # (K,) NaN on non-eval rounds
    granted: jax.Array          # (K,) int32 active (granted) cohort size
    quarantined: jax.Array      # (K,) int32 quarantined cohort rows (§19)


def _make_scan_body(model: ClassifierModel, ccfg: ClientConfig,
                    spec: ScanSpec):
    """The shared per-round scan body: selection, training, GTG-Shapley,
    valuation update, cond-gated eval.  `make_run_scan` (whole run) and
    `make_segment_step` (K-round segment) scan the SAME body, which is
    what makes segmented execution bit-identical to the fused run."""
    from repro.telemetry.trace import attach_live_tap, named_stage

    round_step = make_round_step(model, ccfg, spec.round)
    uses_losses = any(sp.uses_local_losses for sp in spec.selectors)
    n_clients = spec.selectors[0].n_clients
    ca = spec.round.client_axis

    def bind(xs_all, ys_all, nv_all, sigma_all, x_val, y_val, x_test,
             y_test, fractions, strategy_id):
        def body(carry, per_round):
            params, sstate, key, eval_slot = carry
            t, epochs_row, fault_row, d_t, do_any, do_mine = per_round
            key, sel_key, round_key = jax.random.split(key, 3)

            if uses_losses:   # Power-of-Choice ranks clients by w^t loss
                losses = jax.vmap(
                    lambda x, y, nv: local_loss(model, params, x, y, nv)
                )(xs_all, ys_all, nv_all)
                if ca is not None:
                    # local rows -> the exact global (N,) loss vector
                    losses = jax.lax.all_gather(losses, ca,
                                                tiled=True)[:n_clients]
            else:
                losses = jnp.zeros((n_clients,), jnp.float32)

            with named_stage("select"):
                # selection is global top-m: under client sharding the
                # per-client state is all-gathered to its exact (N,) form,
                # the strategy runs unchanged, and the updated vectors are
                # scattered back to this shard's block (DESIGN.md §16)
                if ca is not None:
                    full, put_back = gather_client_state(sstate, ca,
                                                         n_clients)
                else:
                    full, put_back = sstate, lambda s: s
                ctx = DeviceSelectionContext(data_fractions=fractions,
                                             local_losses=losses, poc_d=d_t)
                sel, full = device_select_any(spec.selectors, strategy_id,
                                              full, sel_key, ctx)
                epochs_k = (cohort_take(epochs_row, sel, axis_name=ca)
                            if ca is not None else jnp.take(epochs_row, sel))
                codes_k = (cohort_take(fault_row, sel, axis_name=ca)
                           if ca is not None else jnp.take(fault_row, sel))
                # active mask at select time: dropout strategies freeze
                # `active` here (`full` is the gathered (N,) view)
                active_sel = jnp.take(full.active, sel)

            out = round_step(params, xs_all, ys_all, nv_all, sigma_all,
                             x_val, y_val, sel, epochs_k, round_key,
                             codes_k)
            # granted cohort size: how many of the m selected clients are
            # active under the strategy's availability mask AND survived
            # the fault mask / quarantine screen — the honest per-round
            # upload multiplier for the byte ledger.  out.ok is all-True
            # when hardening is off, so this matches the pre-§19 value.
            granted = jnp.sum((active_sel & out.ok).astype(jnp.int32))
            sstate = put_back(device_update_any(
                spec.selectors, strategy_id, full, sel,
                out.sv if spec.round.needs_sv else None))

            if spec.live_tap:
                # opt-in in-scan stream (§15): host callback per round,
                # value-neutral (nothing downstream reads from it)
                attach_live_tap(t, strategy_id, sel, out.sv,
                                out.utility_evals, out.sv_truncated)

            # table-driven eval (DESIGN.md §13): `do_any` is the OR of the
            # replicas' eval-mask rows and reaches the trace UNBATCHED, so
            # the cond survives the replica vmap as a real branch — the
            # round evaluates only where some replica's mask is set;
            # `do_mine` (this replica's row) masks out the writes of
            # replicas whose own cadence is off this round
            nan = jnp.full((), jnp.nan, jnp.float32)
            with named_stage("eval"):
                acc, vloss = jax.lax.cond(
                    do_any,
                    lambda p: (model.accuracy(p, x_test, y_test),
                               model.loss(p, x_val, y_val)),
                    lambda p: (nan, nan),
                    out.params)
            acc = jnp.where(do_mine, acc, nan)
            vloss = jnp.where(do_mine, vloss, nan)
            eval_slot = eval_slot + do_mine.astype(jnp.int32)

            ys = (sel, epochs_k, out.sv, out.utility_evals,
                  out.sv_truncated, acc, vloss, granted, out.quarantined)
            return (out.params, sstate, key, eval_slot), ys

        return body

    return bind


def make_segment_step(model: ClassifierModel, ccfg: ClientConfig,
                      spec: ScanSpec) -> Callable[..., SegmentOutput]:
    """Build the traceable K-round segment: the carry-in/carry-out contract.

    Signature of the returned fn:
        (carry: SegmentCarry, t0, eval_any_seg, xs_all, ys_all, nv_all,
         sigma_all, x_val, y_val, x_test, y_test, fractions, epochs_seg,
         fault_seg, d_seg, eval_seg, strategy_id) -> SegmentOutput
    where K = spec.rounds_per_segment (or spec.rounds when 0), t0 is the
    () int32 GLOBAL index of the segment's first round, epochs_seg is
    (K, N) int32, fault_seg (K, N) int32 fault codes (§19, zeros when
    faults are off), d_seg (K,) int32, and eval_seg (K,) bool — the
    [t0, t0+K) slices of the whole-run tables (`schedule.eval_mask`).
    `eval_any_seg` is the (K,) bool OR of ALL replicas' eval rows and,
    like t0, stays UNBATCHED under the replica vmap so the in-scan eval
    cond remains a real branch.  Chaining T/K segment calls from t0=0
    reproduces `make_run_scan` bit-for-bit: same body, same carry, same
    key stream.
    """
    k_rounds = spec.rounds_per_segment or spec.rounds
    bind = _make_scan_body(model, ccfg, spec)

    def segment_step(carry, t0, eval_any_seg, xs_all, ys_all, nv_all,
                     sigma_all, x_val, y_val, x_test, y_test, fractions,
                     epochs_seg, fault_seg, d_seg, eval_seg,
                     strategy_id) -> SegmentOutput:
        body = bind(xs_all, ys_all, nv_all, sigma_all, x_val, y_val,
                    x_test, y_test, fractions, strategy_id)
        ts = t0 + jnp.arange(k_rounds)
        (params, sstate, key, eval_slot), ys = jax.lax.scan(
            body, (carry.params, carry.sel_state, carry.key,
                   carry.eval_slot),
            (ts, epochs_seg, fault_seg, d_seg, eval_any_seg, eval_seg))
        sels, epochs, sv, evals, trunc, acc, vloss, granted, quar = ys
        return SegmentOutput(SegmentCarry(params, sstate, key, eval_slot),
                             sels, epochs, sv, evals, trunc, acc, vloss,
                             granted, quar)

    return segment_step


def make_run_scan(model: ClassifierModel, ccfg: ClientConfig,
                  spec: ScanSpec) -> Callable[..., ScanRunOutput]:
    """Build the traceable whole-run function: T rounds in ONE `lax.scan`.

    Selection, the straggler E_k gather, local training, GTG-Shapley, the
    valuation update, and the (cond-gated) eval all live inside the scan
    body, so a full T-round run — strategy logic included — executes as a
    single dispatch.  Per-round key-splitting matches the host engines
    (`split(key, 3)` then `cohort_update`'s `split(round_key, M+1)`), so
    selections are bit-identical to `engine="batched"` at the same seed.

    Signature of the returned fn:
        (params, xs_all, ys_all, nv_all, sigma_all, x_val, y_val,
         x_test, y_test, fractions, epochs_table, fault_table, d_sched,
         eval_table, strategy_id, sel_state, key) -> ScanRunOutput
    where epochs_table is (T, N) int32 (see engine.schedule tables),
    fault_table is the (T, N) int32 fault-code table (§19, zeros when
    faults are off), d_sched is (T,) int32 Power-of-Choice candidate
    counts, eval_table is the (T,) bool `schedule.eval_mask` row, and
    strategy_id picks from spec.selectors (ignored when len == 1).
    """
    whole = (spec if spec.rounds_per_segment in (0, spec.rounds)
             else spec._replace(rounds_per_segment=0))
    segment = make_segment_step(model, ccfg, whole)

    def run_scan(params, xs_all, ys_all, nv_all, sigma_all, x_val, y_val,
                 x_test, y_test, fractions, epochs_table, fault_table,
                 d_sched, eval_table, strategy_id, sel_state,
                 key) -> ScanRunOutput:
        carry = SegmentCarry(params, sel_state, key,
                             jnp.zeros((), jnp.int32))
        out = segment(carry, jnp.asarray(0, jnp.int32), eval_table,
                      xs_all, ys_all, nv_all, sigma_all, x_val, y_val,
                      x_test, y_test, fractions, epochs_table, fault_table,
                      d_sched, eval_table, strategy_id)
        return ScanRunOutput(out.carry.params, out.carry.sel_state,
                             out.selections, out.epochs, out.sv,
                             out.utility_evals, out.sv_truncated,
                             out.test_acc, out.val_loss, out.granted,
                             out.quarantined, out.carry.eval_slot)

    return run_scan


@functools.lru_cache(maxsize=16)
def _jitted_run_scan_cached(model, ccfg, spec, donate, vmapped):
    fn = make_run_scan(model, ccfg, spec)
    if vmapped:
        fn = jax.vmap(fn)
    return jax.jit(fn, donate_argnums=donate)


@functools.lru_cache(maxsize=16)
def _jitted_segment_step_cached(model, ccfg, spec, donate, vmapped):
    fn = make_segment_step(model, ccfg, spec)
    if vmapped:
        # the carry and every operand are replica-stacked; only t0 (the
        # global round offset) and eval_any_seg (the OR of the replicas'
        # eval rows) are shared, keeping the eval cond unbatched
        fn = jax.vmap(fn, in_axes=(0, None, None) + (0,) * 14)
    return jax.jit(fn, donate_argnums=donate)


def jitted_segment_step(model: ClassifierModel, ccfg: ClientConfig,
                        spec: ScanSpec, *, vmapped: bool = False):
    """Process-wide (bounded) cache of compiled K-round segment steps —
    one executable serves every segment of every replica batch sharing
    (model, client cfg, spec), so a T/K-segment run still pays exactly
    one trace+compile and one dispatch per segment."""
    donate = (0,) if jax.default_backend() in ("tpu", "gpu") else ()
    return _jitted_segment_step_cached(model, ccfg, spec, donate, vmapped)


def jitted_run_scan(model: ClassifierModel, ccfg: ClientConfig,
                    spec: ScanSpec, *, vmapped: bool = False):
    """Process-wide (bounded) cache of compiled whole-run scans, mirroring
    `jitted_round_step`: every seed of a benchmark table cell reuses one
    trace and one executable."""
    donate = (0,) if jax.default_backend() in ("tpu", "gpu") else ()
    return _jitted_run_scan_cached(model, ccfg, spec, donate, vmapped)


class RoundEngine:
    """Owns the compiled `round_step` plus the per-run constant operands.

    One instance per `run_federated` call: the full padded client stacks,
    privacy sigmas, and validation split are bound once; per round only
    (params, sel, epochs_k, key) cross the host boundary — a single
    dispatch, vs O(M) for the legacy loop.
    """

    def __init__(self, model: ClassifierModel, ccfg: ClientConfig,
                 spec: RoundSpec, xs_all, ys_all, nv_all, sigma_all,
                 x_val, y_val):
        self.spec = spec
        self._step = jitted_round_step(model, ccfg, spec)
        self._operands = (jnp.asarray(xs_all), jnp.asarray(ys_all),
                          jnp.asarray(nv_all), jnp.asarray(sigma_all),
                          jnp.asarray(x_val), jnp.asarray(y_val))

    def step(self, params: PyTree, sel, epochs_k, round_key,
             fault_codes=None) -> RoundOutput:
        """Execute one full communication round as one dispatch."""
        if fault_codes is None:
            fault_codes = jnp.zeros((len(sel),), jnp.int32)
        return self._step(params, *self._operands, jnp.asarray(sel),
                          jnp.asarray(epochs_k), round_key,
                          jnp.asarray(fault_codes, jnp.int32))

    def upload_nbytes_per_client(self, params: PyTree) -> int:
        """Wire bytes of one client upload under this spec's codec."""
        return codec_nbytes(self.spec.upload_codec, params)

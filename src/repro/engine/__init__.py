"""repro.engine — fused round/run execution engines (DESIGN.md §6, §11).

    batch_client  vmapped ClientUpdate over the selected cohort
    round_engine  fused single-dispatch `round_step`, whole-run `run_scan`,
                  and the K-round `segment_step` carry contract (§12)
    scan_engine   engine="scan" orchestration: T rounds as ONE dispatch
    replicated    replica vmaps: per-round (seeds) and whole-run
                  (strategies x seeds — delegates to repro.grid)
    schedule      virtual clock: latencies, deadlines, time-derived E_k
"""
from repro.engine.batch_client import batched_client_update, cohort_update
from repro.engine.round_engine import (
    RoundEngine, RoundOutput, RoundSpec, ScanRunOutput, ScanSpec,
    SegmentCarry, SegmentOutput, jitted_run_scan, jitted_segment_step,
    make_run_scan, make_segment_step,
)
from repro.engine.schedule import (
    ClientClock, ScheduleConfig, VirtualClock, deadline_epochs,
    deadline_epochs_table, eval_mask, make_client_clock, round_duration_s,
    straggler_epochs_table,
)

__all__ = [
    "batched_client_update", "cohort_update",
    "RoundEngine", "RoundOutput", "RoundSpec",
    "ScanRunOutput", "ScanSpec", "SegmentCarry", "SegmentOutput",
    "jitted_run_scan", "jitted_segment_step", "make_run_scan",
    "make_segment_step",
    "ClientClock", "ScheduleConfig", "VirtualClock", "deadline_epochs",
    "deadline_epochs_table", "eval_mask", "make_client_clock",
    "round_duration_s",
    "straggler_epochs_table",
]

"""repro.engine — the batched round-execution engine (DESIGN.md §6).

    batch_client  vmapped ClientUpdate over the selected cohort
    round_engine  the fused single-dispatch `round_step` + RoundEngine
    replicated    multi-seed vmap: S replicas per dispatch
    schedule      virtual clock: latencies, deadlines, time-derived E_k
"""
from repro.engine.batch_client import batched_client_update, cohort_update
from repro.engine.round_engine import RoundEngine, RoundOutput, RoundSpec
from repro.engine.schedule import (
    ClientClock, ScheduleConfig, VirtualClock, deadline_epochs,
    make_client_clock, round_duration_s,
)

__all__ = [
    "batched_client_update", "cohort_update",
    "RoundEngine", "RoundOutput", "RoundSpec",
    "ClientClock", "ScheduleConfig", "VirtualClock", "deadline_epochs",
    "make_client_clock", "round_duration_s",
]

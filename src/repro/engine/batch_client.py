"""Batched ClientUpdate — the cohort of M selected clients as ONE program.

The legacy server loop dispatches `client_update` M times per round from
Python; every dispatch pays host-side overhead and XLA sees M disjoint
programs it cannot fuse.  Client datasets are already padded and stacked as
`(N, cap, ...)` (see `server._pad_clients`), so the natural execution is:
gather the selected rows with one `take`, then `vmap` the shared local-SGD
step over the cohort axis.  XLA fuses the M local trainings into batched
matmuls; on a mesh the cohort axis shards over "data" (DESIGN.md §6).

Key-derivation parity: `cohort_update` splits the round key exactly like the
legacy loop (`split(round_key, M+1)`, client i takes key i, the Shapley pass
takes the last) so loop and batched engines are bit-compatible per client.
"""
from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.federated.client import ClientConfig, client_update
from repro.kernels.cohort_gather import cohort_take
from repro.models.mlp_cnn import ClassifierModel

PyTree = Any


def batched_client_update(
    model: ClassifierModel,
    ccfg: ClientConfig,
    params: PyTree,          # replicated server model w^t
    xs: jax.Array,           # (M, cap, ...) cohort padded data
    ys: jax.Array,           # (M, cap)
    n_valid: jax.Array,      # (M,)
    epochs_k: jax.Array,     # (M,) straggler/deadline-adjusted local epochs
    sigma_k: jax.Array,      # (M,) privacy noise levels
    keys: jax.Array,         # (M,) rng keys
) -> PyTree:
    """vmap of ClientUpdate over the cohort; leaves come back (M, *shape)."""
    return jax.vmap(
        lambda x, y, n, e, s, k: client_update(model, ccfg, params, x, y, n,
                                               e, s, k)
    )(xs, ys, n_valid, epochs_k, sigma_k, keys)


def cohort_update(
    model: ClassifierModel,
    ccfg: ClientConfig,
    params: PyTree,
    xs_all: jax.Array,       # (N, cap, ...) ALL clients' padded data
    ys_all: jax.Array,       # (N, cap)
    nv_all: jax.Array,       # (N,)
    sigma_all: jax.Array,    # (N,)
    sel: jax.Array,          # (M,) int selected client ids
    epochs_k: jax.Array,     # (M,)
    round_key: jax.Array,
    client_axis: str = None,
) -> tuple[PyTree, jax.Array, jax.Array]:
    """Gather the cohort out of the full stacks and train it in one vmap.

    Returns (stacked updates, n_k of the cohort, shapley key).  Designed to
    be traced inside the fused `round_step` (and vmapped over seeds), so the
    gather happens on-device — no host round-trip per client.

    With `client_axis` set the `*_all` stacks are this shard's local
    blocks of client-axis-sharded arrays (DESIGN.md §16) and the gather
    goes cross-shard through `cohort_take`; `sel` stays global.  Either
    way the gathered cohort is bitwise the dense `jnp.take` result.
    """
    m = sel.shape[0]
    ckeys = jax.random.split(round_key, m + 1)
    xs = cohort_take(xs_all, sel, axis_name=client_axis)
    ys = cohort_take(ys_all, sel, axis_name=client_axis)
    nv = cohort_take(nv_all, sel, axis_name=client_axis)
    sg = cohort_take(sigma_all, sel, axis_name=client_axis)
    stacked = batched_client_update(model, ccfg, params, xs, ys, nv,
                                    epochs_k, sg, ckeys[:m])
    return stacked, nv.astype(jnp.float32), ckeys[m]


@partial(jax.jit, static_argnames=("model", "ccfg"))
def jit_batched_client_update(model, ccfg, params, xs, ys, n_valid, epochs_k,
                              sigma_k, keys):
    """Standalone jitted entry point (benchmarks / interactive use)."""
    return batched_client_update(model, ccfg, params, xs, ys, n_valid,
                                 epochs_k, sigma_k, keys)

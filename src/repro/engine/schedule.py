"""Virtual-clock systems model: latencies, deadlines, time-derived stragglers.

The paper's Section IV induces stragglers by *drawing* E_k ~ U{1..E} for a
random x-fraction of clients.  Real deployments produce stragglers from
*time*: a client has a compute rate and a link bandwidth, the server sets a
round deadline tau, and the client completes however many local epochs fit:

    E_k = clip( floor( (tau - t_comm_k) / t_epoch_k ), 0, E )

This module provides that model as a first-class workload.  Per-client
epoch times are drawn log-normal (the canonical device-speed distribution;
cf. heterogeneity-aware FL systems work), optionally scaled by the client's
dataset size (more data => a slower epoch).  The communication term charges
a full model download + upload per round at the client's link speed.

A `VirtualClock` accumulates simulated wall time across rounds — the round
duration is the slowest selected client, cut off at the deadline — so runs
report time-to-accuracy in *simulated seconds*, not just rounds.  All of it
is host-side numpy bookkeeping: the derived `E_k` feeds the same
`epochs_k` argument of the batched/loop engines, so the compiled round step
is untouched by scheduling policy.
"""
from __future__ import annotations

import dataclasses
import math
from typing import NamedTuple, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class ScheduleConfig:
    """Timing model for one federated deployment."""
    deadline_s: float = 1.0          # tau: round deadline (simulated seconds)
    epoch_time_mean_s: float = 0.25  # median per-epoch compute time
    epoch_time_sigma: float = 0.5    # log-normal spread of device speeds
    uplink_bytes_per_s: float = 1e8
    downlink_bytes_per_s: float = 4e8
    data_scaled: bool = True         # epoch time grows with n_k / mean(n_k)


class ClientClock(NamedTuple):
    epoch_time_s: np.ndarray   # (N,) per-local-epoch compute time
    comm_time_s: np.ndarray    # (N,) per-round download + upload time


def make_client_clock(scfg: ScheduleConfig, n_clients: int, model_bytes: int,
                      rng: np.random.Generator,
                      n_k: Optional[np.ndarray] = None) -> ClientClock:
    """Draw the static per-client timing profile for a run."""
    epoch_t = rng.lognormal(mean=math.log(scfg.epoch_time_mean_s),
                            sigma=scfg.epoch_time_sigma,
                            size=n_clients).astype(np.float64)
    if scfg.data_scaled and n_k is not None:
        n_k = np.asarray(n_k, np.float64)
        epoch_t = epoch_t * (n_k / max(n_k.mean(), 1.0))
    comm_t = np.full(n_clients,
                     model_bytes / scfg.downlink_bytes_per_s
                     + model_bytes / scfg.uplink_bytes_per_s, np.float64)
    return ClientClock(epoch_time_s=epoch_t, comm_time_s=comm_t)


def deadline_epochs(clock: ClientClock, scfg: ScheduleConfig,
                    sel: np.ndarray, max_epochs: int) -> np.ndarray:
    """(M,) int32 local epochs each selected client completes before tau.

    A client whose transfer alone exceeds the deadline contributes 0 epochs
    (it uploads the unchanged broadcast model — pure noise-floor weight).
    """
    sel = np.asarray(sel)
    budget = scfg.deadline_s - clock.comm_time_s[sel]
    e = np.floor(budget / np.maximum(clock.epoch_time_s[sel], 1e-12))
    return np.clip(e, 0, max_epochs).astype(np.int32)


def round_duration_s(clock: ClientClock, scfg: ScheduleConfig,
                     sel: np.ndarray, epochs_k: np.ndarray) -> float:
    """Simulated duration of one round: the slowest selected client, capped
    at the deadline (the server proceeds at tau regardless)."""
    sel = np.asarray(sel)
    t = clock.comm_time_s[sel] + np.asarray(epochs_k) * clock.epoch_time_s[sel]
    if t.size == 0:
        return 0.0
    return float(np.minimum(t, scfg.deadline_s).max())


@dataclasses.dataclass
class VirtualClock:
    """Accumulates simulated seconds across rounds."""
    now_s: float = 0.0

    def advance(self, dt_s: float) -> float:
        self.now_s += float(dt_s)
        return self.now_s


# ---------------------------------------------------------------------------
# Whole-run epoch tables for the scan engine (DESIGN.md §11).
#
# The loop/batched engines derive each round's E_k on the host *after*
# selection; the scan engine selects on-device inside one compiled program,
# so every round's per-client budget must exist up front as a (T, N) int32
# operand the trace gathers rows from.
# ---------------------------------------------------------------------------

def deadline_epochs_table(clock: ClientClock, scfg: ScheduleConfig,
                          rounds: int, max_epochs: int) -> np.ndarray:
    """(T, N) int32 deadline-derived budgets — the timing profile is static,
    so every round repeats the same row (exactly `deadline_epochs` for every
    client, keeping scan/batched/loop engines bit-identical)."""
    n = clock.epoch_time_s.shape[0]
    row = deadline_epochs(clock, scfg, np.arange(n), max_epochs)
    return np.tile(row, (rounds, 1))


def eval_mask(rounds: int, eval_every: int) -> np.ndarray:
    """(T,) bool eval table: evaluate after round t iff the mask is set.

    THE single definition of the eval cadence (DESIGN.md §13): round t
    evaluates when ``(t + 1) % eval_every == 0``, and the final round
    always evaluates — so ``eval_every > rounds`` yields exactly one eval.
    Every engine consumes this table instead of re-deriving the predicate;
    under the replica vmap the stacked ``(R, T)`` rows give each replica
    its own cadence.
    """
    if eval_every < 1:
        raise ValueError(f"eval_every must be >= 1, got {eval_every}")
    mask = (np.arange(1, rounds + 1) % eval_every) == 0
    if rounds > 0:
        mask[-1] = True
    return mask


def straggler_epochs_table(rng: np.random.Generator, rounds: int,
                           n_clients: int, straggler_ids, max_epochs: int
                           ) -> np.ndarray:
    """(T, N) int32 budgets under the paper's random-straggler model:
    straggler k completes E_tk ~ U{1..E} in round t, everyone else E.

    The table fills (round-major, client id ascending) from one vectorized
    draw — a fresh stream, NOT the legacy engines' lazily-consumed
    per-selection draws, which cannot be replayed once selection happens
    on-device.  With straggler_frac > 0 the scan engine is therefore
    distribution-identical but not stream-identical to loop/batched
    (DESIGN.md §11)."""
    table = np.full((rounds, n_clients), max_epochs, np.int32)
    ids = sorted(int(k) for k in straggler_ids)
    if ids:
        table[:, ids] = rng.integers(1, max_epochs + 1,
                                     size=(rounds, len(ids)))
    return table

"""Multi-seed / multi-strategy replication: R independent FL runs fused.

Every benchmark table re-runs each (strategy, knob) cell across seeds; run
solo, each seed pays its own compilation and its own per-round dispatches.
Two fused paths live here:

  * `run_replicated` — the PR-1 contract: the fused `round_step`
    (round_engine.py) is vmapped over a leading seed axis and jitted ONCE;
    per round, a single dispatch advances all S replicas.  Strategy calls
    (the `selection_jax` select/update pair, E_k draws) stay per-seed
    host orchestration, keeping each replica's rng/key streams identical
    to a solo `run_federated(..., engine="batched")` run at the same seed.

  * `run_replicated_scan` — the whole-run `lax.scan` program vmapped over
    the replica axis, selector state included: a T-round, R-replica table
    is ONE dispatch per capability partition.  Replicas may differ in
    *strategy* as well as seed — since PR-3 this delegates to
    `repro.grid.run_grid` (DESIGN.md §12), which partitions the grid so
    non-SV strategies skip GTG-Shapley, segments the scan for
    checkpoint/resume, and shards the replica axis over local devices.

Replicas may have different per-client capacities (each seed re-partitions
its data); stacks are padded to the max capacity — padding is never read
because minibatch indices are sampled below each client's `n_valid`.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregation import tree_stack
from repro.engine.round_engine import RoundSpec, jitted_round_step
from repro.engine.schedule import VirtualClock, eval_mask, round_duration_s
from repro.federated.client import local_loss
from repro.federated.compression import codec_nbytes


def _pad_cap(arr: np.ndarray, cap: int) -> np.ndarray:
    """Zero-pad axis 1 (per-client capacity) of (N, cap_i, ...) to `cap`."""
    if arr.shape[1] == cap:
        return arr
    widths = [(0, 0), (0, cap - arr.shape[1])] + [(0, 0)] * (arr.ndim - 2)
    return np.pad(arr, widths)


def run_replicated(cfg, seeds, data=None, model=None):
    """See `federated.server.run_federated_replicated` (the public alias)."""
    from repro.core.selection_jax import (
        DeviceSelectionContext, jitted_selector, poc_d_schedule,
    )
    from repro.federated.server import (
        FLResult, round_epochs, setup_run,
    )

    from repro.telemetry.trace import CompileTimer

    t_start = time.perf_counter()
    seeds = list(seeds)
    if not seeds:
        raise ValueError("run_federated_replicated needs at least one seed")
    ctimer = CompileTimer()
    with ctimer:
        setups = [setup_run(dataclasses.replace(cfg, seed=s), data, model)
                  for s in seeds]
    model = setups[0].model
    n_seeds = len(seeds)

    # ---- stack per-seed state along a leading replica axis ---------------
    cap = max(int(s.xs.shape[1]) for s in setups)
    xs = jnp.asarray(np.stack([_pad_cap(np.asarray(s.xs), cap)
                               for s in setups]))
    ys = jnp.asarray(np.stack([_pad_cap(np.asarray(s.ys), cap)
                               for s in setups]))
    nv = jnp.asarray(np.stack([np.asarray(s.n_valid) for s in setups]))
    sigma = jnp.asarray(np.stack([s.sigma_k_all for s in setups]))
    x_val = jnp.asarray(np.stack([np.asarray(s.x_val) for s in setups]))
    y_val = jnp.asarray(np.stack([np.asarray(s.y_val) for s in setups]))
    x_test = jnp.asarray(np.stack([np.asarray(s.x_test) for s in setups]))
    y_test = jnp.asarray(np.stack([np.asarray(s.y_test) for s in setups]))
    params = tree_stack([s.params for s in setups])
    keys = [s.key for s in setups]
    states = [s.sel_state for s in setups]

    # one cfg replicated across seeds => one spec shared by every replica
    sel_spec = setups[0].sel_spec
    dev_select, dev_update = jitted_selector(sel_spec)
    d_sched = poc_d_schedule(sel_spec, cfg.rounds)
    emask = eval_mask(cfg.rounds, cfg.eval_every)
    needs_sv = sel_spec.uses_shapley
    max_iters = cfg.shapley_max_iters or 50 * cfg.m
    spec = RoundSpec(needs_sv=needs_sv, shapley_impl=cfg.shapley_impl,
                     shapley_eps=cfg.shapley_eps, shapley_max_iters=max_iters,
                     sv_chunk=cfg.sv_chunk, upload_codec=cfg.upload_codec,
                     faults=cfg.faults, quarantine=cfg.quarantine,
                     quarantine_z=cfg.quarantine_z)
    step_rep = jitted_round_step(model, cfg.client, spec, vmapped=True)
    hardened = cfg.faults is not None or cfg.quarantine

    uses_losses = sel_spec.uses_local_losses
    losses_rep = jax.jit(jax.vmap(jax.vmap(
        lambda p, x, y, n: local_loss(model, p, x, y, n),
        in_axes=(None, 0, 0, 0))))
    eval_rep = jax.jit(jax.vmap(model.accuracy))
    vloss_rep = jax.jit(jax.vmap(lambda p, xv, yv: model.loss(p, xv, yv)))

    codec_bytes = codec_nbytes(cfg.upload_codec, setups[0].params)
    model_bytes = setups[0].model_bytes
    fractions_rep = [jnp.asarray(s.fractions) for s in setups]
    zero_losses = jnp.zeros((cfg.n_clients,), jnp.float32)
    vclocks = [VirtualClock() if s.clock is not None else None
               for s in setups]

    test_acc = [[] for _ in seeds]
    val_loss_hist = [[] for _ in seeds]
    selections = [[] for _ in seeds]
    total_evals = [0] * n_seeds
    upload_bytes = [0] * n_seeds
    download_bytes = [0] * n_seeds
    quar_totals = [0] * n_seeds
    dispatches = 0

    # jit compiles during the rounds (first dispatch of each cached
    # program) are attributed to compile_time_s by the active timer
    with ctimer:
        for t in range(cfg.rounds):
            # ---- per-replica host-side strategy logic ------------------------
            sel_rows, epoch_rows, key_rows, code_rows = [], [], [], []
            losses_all = None
            if uses_losses:
                losses_all = losses_rep(params, xs, ys, nv)
                dispatches += 1
            for i, s in enumerate(setups):
                keys[i], sel_key, round_key = jax.random.split(keys[i], 3)
                ctx = DeviceSelectionContext(
                    data_fractions=fractions_rep[i],
                    local_losses=losses_all[i] if uses_losses else zero_losses,
                    poc_d=jnp.asarray(d_sched[t]))
                sel_dev, states[i] = dev_select(states[i], sel_key, ctx)
                sel = np.asarray(sel_dev, np.int64)
                selections[i].append(sel)
                sel_rows.append(sel)
                epoch_rows.append(round_epochs(cfg, s, sel, t))
                key_rows.append(round_key)
                code_rows.append(
                    np.asarray(s.fault_table[t][sel], np.int32)
                    if s.fault_table is not None
                    else np.zeros(len(sel), np.int32))
                if not hardened:
                    # ok-gated post-dispatch when hardened (§19)
                    upload_bytes[i] += codec_bytes * len(sel)
                download_bytes[i] += model_bytes * len(sel)
                if vclocks[i] is not None:
                    vclocks[i].advance(round_duration_s(
                        s.clock, cfg.schedule, sel, epoch_rows[-1]))

            # ---- ONE dispatch advances every replica -------------------------
            out = step_rep(params, xs, ys, nv, sigma, x_val, y_val,
                           jnp.asarray(np.stack(sel_rows)),
                           jnp.asarray(np.stack(epoch_rows)),
                           jnp.stack(key_rows),
                           jnp.asarray(np.stack(code_rows)))
            params = out.params
            dispatches += 1
            if hardened:
                ok_rows = np.asarray(out.ok)
                quar_rows = np.asarray(out.quarantined)
                for i in range(n_seeds):
                    upload_bytes[i] += codec_bytes * int(ok_rows[i].sum())
                    quar_totals[i] += int(quar_rows[i])

            sv_rows = np.asarray(out.sv) if needs_sv else None
            evals_rows = np.asarray(out.utility_evals)
            for i in range(n_seeds):
                sv_i = jnp.asarray(sv_rows[i]) if needs_sv else None
                if needs_sv:
                    total_evals[i] += int(evals_rows[i])
                states[i] = dev_update(states[i], jnp.asarray(sel_rows[i]),
                                       sv_i)

            if emask[t]:
                accs = np.asarray(eval_rep(params, x_test, y_test))
                vls = np.asarray(vloss_rep(params, x_val, y_val))
                dispatches += 2
                for i in range(n_seeds):
                    test_acc[i].append((t + 1, float(accs[i])))
                    val_loss_hist[i].append((t + 1, float(vls[i])))

    wall = time.perf_counter() - t_start
    results = []
    for i, s in enumerate(setups):
        params_i = jax.tree.map(lambda x: x[i], params)
        results.append(FLResult(
            config=dataclasses.replace(cfg, seed=seeds[i]),
            test_acc=test_acc[i],
            val_loss=val_loss_hist[i],
            final_acc=test_acc[i][-1][1] if test_acc[i] else float("nan"),
            sv_final=np.asarray(states[i].valuation.sv),
            selection_counts=np.asarray(states[i].valuation.counts),
            selections=selections[i],
            shapley_evals=total_evals[i],
            wall_time_s=wall,          # shared: the replicas ran fused
            params=params_i,
            upload_bytes=upload_bytes[i],
            download_bytes=download_bytes[i],
            sim_time_s=vclocks[i].now_s if vclocks[i] is not None else 0.0,
            dispatches=dispatches,     # shared across the fused run
            compile_time_s=ctimer.seconds,
            execute_time_s=max(wall - ctimer.seconds, 0.0),
            quarantined_total=quar_totals[i],
        ))
    return results


def run_replicated_scan(cfg, seeds, selectors: Optional[Sequence[str]] = None,
                        data=None, model=None, **grid_kwargs):
    """Seeds × strategies, each a full T-round run, fused on-device.

    `selectors=None` replicates `cfg.selector` across `seeds` (each replica
    reproduces a solo `run_federated(..., engine="scan")` at its seed).
    With a list of registry names the replica batch becomes the full
    strategies × seeds grid.  Since PR-3 this is a thin wrapper over
    `repro.grid.run_grid` (DESIGN.md §12): cells are partitioned by
    capability, so FedAvg/random replicas of a mixed grid no longer pay
    the GTG-Shapley superset cost — each partition is one scan dispatch
    (per segment), and non-SV replicas report shapley_evals = 0.
    `grid_kwargs` (rounds_per_segment, checkpoint_dir, shard, ...) pass
    through to `run_grid`.

    Returns a flat list of FLResults in (selector-major, seed-minor) order.
    """
    from repro.grid import GridSpec, run_grid

    seeds = list(seeds)
    if not seeds:
        raise ValueError("run_replicated_scan needs at least one seed")
    gspec = GridSpec.product(cfg, selectors=selectors, seeds=seeds)
    out = run_grid(gspec, data=data, model=model, **grid_kwargs)
    return out.results

"""Chaos harness: convergence under injected client faults + overhead.

The §19 fault layer promises three things this bench pins as numbers:

  * the pre-drawn fault table is part of the config seed, so the
    quarantine counts of a fixed (selector, rate) cell are DETERMINISTIC
    — regress.py watches them with a zero band;
  * GreedyFed's accuracy degrades gracefully as the byzantine/crash rate
    rises when quarantine is on (the convergence-under-fault-rate curve,
    greedyfed vs random on the same tables);
  * the hardened round program costs ~nothing extra when nothing fires:
    quarantine-on-but-clean vs stock scan us-per-round.

    PYTHONPATH=src python -m benchmarks.fault_bench --smoke --json BENCH_faults.json

(opt-in: not part of the default `benchmarks.run` sweep; `make
faults-smoke` runs the smoke shape and `CHECK_FAULTS=1 scripts/check.sh`
gates it in CI.)
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from benchmarks.fl_common import DIFFICULTY
from repro.data.synth import make_dataset
from repro.faults import FaultSpec
from repro.federated.client import ClientConfig
from repro.federated.server import FLConfig, run_federated
from repro.grid import GridSpec, run_grid
from repro.telemetry import write_bench_json

SELECTORS = ["greedyfed", "random"]
RATES = (0.0, 0.2, 0.5)
KINDS = ("nan", "sign_flip", "crash")

SMOKE = dict(
    n_clients=12, m=4, rounds=12, n_train=600, n_val=100, n_test=200,
    eval_every=4, shapley_max_iters=10,
    client=ClientConfig(epochs=2, batches_per_epoch=2, batch_size=16),
)
FULL = dict(
    n_clients=40, m=4, rounds=35, n_train=4000, n_val=500, n_test=800,
    eval_every=7, shapley_max_iters=20,
    client=ClientConfig(epochs=3, batches_per_epoch=3, batch_size=32),
)


def _rate_key(rate: float) -> str:
    """"rate20" for 0.2 — regress.py path keys must not contain dots."""
    return f"rate{int(round(rate * 100)):02d}"


def fault_rate_curves(base: FLConfig, data, seeds) -> dict:
    """One run_grid call per fault rate: selectors x seeds under the same
    pre-drawn tables, quarantine on.  Returns the curve rows plus the
    deterministic per-(rate, selector) quarantine counts."""
    import dataclasses

    curves = []
    counts: dict = {}
    for rate in RATES:
        faults = (FaultSpec(rate=rate, kinds=KINDS, scale=10.0)
                  if rate > 0 else None)
        cfg = dataclasses.replace(base, faults=faults, quarantine=True)
        spec = GridSpec.product(cfg, selectors=SELECTORS, seeds=list(seeds))
        grid = run_grid(spec, data=[data[s] for c in SELECTORS for s in seeds])
        cells: dict = {}
        for cell, res in zip(spec.cells, grid.results):
            row = cells.setdefault(cell.selector, {
                "final_acc": [], "quarantined_total": 0, "upload_mb": 0.0})
            row["final_acc"].append(res.final_acc)
            row["quarantined_total"] += int(res.quarantined_total)
            row["upload_mb"] += res.upload_bytes / 1e6
        for sel, row in cells.items():
            row["final_acc"] = float(np.mean(row["final_acc"]))
        curves.append({"rate": rate, "cells": cells})
        if rate > 0:
            counts[_rate_key(rate)] = {
                sel: cells[sel]["quarantined_total"] for sel in cells}
    return {"curves": curves, "quarantine_counts": counts}


def quarantine_overhead(base: FLConfig, data, *, repeats: int = 3) -> dict:
    """us-per-round of the hardened-but-clean scan vs the stock scan.

    Both paths are warmed (compile excluded), timed as min-of-repeats;
    the contract is ~0% overhead when the screen never fires."""
    import dataclasses

    timings = {}
    for name, kw in (("off", {}), ("on", {"quarantine": True})):
        cfg = dataclasses.replace(base, **kw)
        run_federated(cfg, data=data)          # warm the executable
        best = min(
            _timed(lambda: run_federated(cfg, data=data))
            for _ in range(repeats))
        timings[name] = best / cfg.rounds * 1e6
    return {
        "us_per_round_off": timings["off"],
        "us_per_round_on": timings["on"],
        "overhead_pct": (timings["on"] / timings["off"] - 1.0) * 100.0,
    }


def _timed(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def run(*, seeds=(0,), smoke=False, json_path=None):
    base_kw = dict(SMOKE if smoke else FULL)
    client = base_kw.pop("client")
    base = FLConfig(dataset="mnist", selector="greedyfed", client=client,
                    engine="scan", **base_kw)
    data = {seed: make_dataset(
        "mnist", n_train=base.n_train, n_val=base.n_val, n_test=base.n_test,
        seed=seed, difficulty=DIFFICULTY) for seed in seeds}

    jax.clear_caches()
    rate_report = fault_rate_curves(base, data, seeds)
    print("# convergence under fault rate (quarantine on)")
    print("rate,selector,final_acc,quarantined,upload_MB")
    for row in rate_report["curves"]:
        for sel, cell in sorted(row["cells"].items()):
            print(f"{row['rate']},{sel},{cell['final_acc']:.4f},"
                  f"{cell['quarantined_total']},{cell['upload_mb']:.2f}")

    overhead = quarantine_overhead(base, data[seeds[0]])
    print(f"# quarantine overhead: on={overhead['us_per_round_on']:.0f}us "
          f"off={overhead['us_per_round_off']:.0f}us "
          f"({overhead['overhead_pct']:+.1f}%)")

    if json_path:
        write_bench_json(json_path, {
            "schema": "bench_faults/v1",
            "seeds": list(seeds), "smoke": smoke,
            "rates": list(RATES), "kinds": list(KINDS),
            "curves": rate_report["curves"],
            "quarantine_counts": rate_report["quarantine_counts"],
            "overhead": overhead,
        })
        print(f"json_report,{json_path}")
    return rate_report


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI shape instead of the quick-bench shape")
    ap.add_argument("--json", default=None,
                    help="write the provenance-stamped BENCH_faults.json")
    a = ap.parse_args()
    run(smoke=a.smoke, json_path=a.json)

"""Aggregate experiments/dryrun/*.json into the §Roofline markdown table."""
from __future__ import annotations

import glob
import json
import os

HERE = os.path.dirname(__file__)
DRYRUN = os.path.join(HERE, "..", "experiments", "dryrun")


def load_records() -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(DRYRUN, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def markdown_table(recs: list[dict], mesh: str = "single") -> str:
    lines = [
        "| arch | shape | compute s | memory s | collective s | dominant | "
        "useful-FLOP ratio | temp GiB/dev |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r.get("status") == "skipped":
            if mesh == "single":
                lines.append(f"| {r['tag'].split('__')[0]} | "
                             f"{r['tag'].split('__')[1]} | — | — | — | "
                             f"skipped: {r['reason']} | — | — |")
            continue
        if not r["tag"].endswith("__" + mesh) or "roofline" not in r:
            continue
        rf = r["roofline"]
        mem = r["memory"].get("temp_size_in_bytes", 0) / 2 ** 30
        lines.append(
            f"| {r['arch']} | {r['shape']} | {rf['compute_s']:.3g} | "
            f"{rf['memory_s']:.3g} | {rf['collective_s']:.3g} | "
            f"{rf['dominant'].replace('_s','')} | "
            f"{rf['useful_flops_ratio']:.2f} | {mem:.1f} |")
    return "\n".join(lines)


def run():
    recs = load_records()
    print(markdown_table(recs, "single"))
    return recs


if __name__ == "__main__":
    run()

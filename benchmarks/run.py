"""Benchmark entry point: one function per paper table/figure + micro/roofline.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only table1,...]

Prints ``name,us_per_call,derived`` CSV for micro-benchmarks and the
accuracy tables for the paper reproductions.  Default (quick) mode scales
the paper protocol down for CPU (benchmarks/fl_common.py); --full uses the
paper's N=300/T=400.
"""
from __future__ import annotations

import argparse
import time

BENCHES = ["kernels", "engine", "table1", "table2", "table3", "table4",
           "fig1", "roofline"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale N=300/T=400 (hours on CPU)")
    ap.add_argument("--only", default=None,
                    help=f"comma list from {BENCHES}")
    ap.add_argument("--seeds", default="0,1")
    ap.add_argument("--engine", default="loop",
                    choices=["loop", "batched", "scan"],
                    help="table execution path; 'scan' fuses each cell's "
                         "seeds into one repro.grid dispatch")
    args = ap.parse_args()
    seeds = tuple(int(s) for s in args.seeds.split(","))
    only = args.only.split(",") if args.only else BENCHES

    t0 = time.time()
    if "kernels" in only:
        from benchmarks.kernel_bench import run as kb
        print("\n# micro-benchmarks (name,us_per_call,derived)")
        for row in kb():
            print(row)

    if "engine" in only:
        from benchmarks.engine_bench import run as eb
        print("\n# round engine: loop vs batched (name,us,derived)")
        for row in eb(full=args.full):
            print(row)

    fl = dict(full=args.full, seeds=seeds, engine=args.engine)
    if "table1" in only:
        from benchmarks.table1_data_heterogeneity import run as t1
        t1(**fl)
    if "table2" in only:
        from benchmarks.table2_timing_constraints import run as t2
        t2(**fl)
    if "table3" in only:
        from benchmarks.table3_stragglers import run as t3
        t3(**fl)
    if "table4" in only:
        from benchmarks.table4_privacy import run as t4
        t4(**fl)
    if "fig1" in only:
        from benchmarks.fig1_convergence import run as f1
        f1(full=args.full, seeds=seeds[:1])
    if "roofline" in only:
        from benchmarks.roofline_table import run as rt
        print("\n# roofline table (from experiments/dryrun — run "
              "`python -m repro.launch.dryrun` first)")
        rt()
    print(f"\n# total bench wall time: {time.time() - t0:.0f}s")


if __name__ == "__main__":
    main()

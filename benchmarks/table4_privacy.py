"""Paper Table IV: accuracy with per-client privacy noise sigma_k."""
from benchmarks.fl_common import print_table, sweep

VALUES = [0.0, 0.05, 0.1]


def run(*, full=False, seeds=(0, 1), dataset="mnist", engine="loop"):
    rows = sweep("privacy_sigma", VALUES, dataset=dataset, seeds=seeds,
                 full=full, engine=engine)
    print_table("Table IV — privacy heterogeneity (sigma)", rows, VALUES)
    return rows


if __name__ == "__main__":
    run()

"""Paper Fig. 1: test accuracy vs communication round (convergence curves).

The paper's Fig. 1 uses CIFAR10; the synthetic CNN task is ~30 s/round on
this 1-core container, so the default shows the same phenomenon on the
MNIST-like task (pass dataset="cifar10" to match the paper exactly).
"""
from benchmarks.fl_common import ALGOS, run_algo


def run(*, full=False, seeds=(0,), dataset="mnist"):
    print("\n# Fig 1 — accuracy vs round (csv: algo,round,acc)")
    curves = {}
    for algo in ALGOS + ["centralized"]:
        out = run_algo(algo, dataset=dataset, seeds=seeds, full=full,
                       eval_every=5)
        curves[algo] = out["curves"]
        for rnd, acc in out["curves"]:
            print(f"{algo},{rnd},{acc:.4f}")
    return curves


if __name__ == "__main__":
    run()

"""Shared FL-benchmark machinery for the paper's tables.

Scaled-down protocol (CPU container): N=30 clients, M=3, T=40 rounds,
synthetic datasets (see data/synth.py), seeds configurable.  Full-paper
settings (N=300, T=400) are reachable with --full; relative orderings are
the validation target (EXPERIMENTS.md §Paper-validation).
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.data.synth import make_dataset
from repro.federated.client import ClientConfig
from repro.federated.server import FLConfig, run_centralized, run_federated

ALGOS = ["greedyfed", "greedyfed_dropout", "ucb", "s_fedavg", "fedavg",
         "fedprox", "power_of_choice"]  # greedyfed_dropout = beyond-paper
                                        # SV-feedback dropout (Sec. VI)

QUICK = dict(
    n_clients=40, m=4, rounds=35, n_train=4000, n_val=500, n_test=800,
    eval_every=7,
    client=ClientConfig(epochs=3, batches_per_epoch=3, batch_size=32),
)
FULL = dict(
    n_clients=300, m=3, rounds=400, n_train=12000, n_val=5000, n_test=5000,
    eval_every=50,
    client=ClientConfig(epochs=5, batches_per_epoch=5, batch_size=32),
)
# synthetic-task hardness calibrated so quick-mode accuracies land mid-range
# (~0.6-0.9) where algorithm orderings are measurable, not saturated
DIFFICULTY = 3.0


def run_algo(algo: str, *, dataset="mnist", seeds=(0, 1), full=False,
             engine="loop", **overrides) -> dict:
    """One benchmark-table cell: `algo` across `seeds`.

    `engine="scan"` routes the multi-seed replication through the grid
    runner (`repro.grid.run_grid`, DESIGN.md §12): every seed is a grid
    cell, all seeds execute as one partitioned scan dispatch.  The other
    engines keep the solo per-seed loop.
    """
    import jax
    # hundreds of (algo x setting x seed) configs each compile their own
    # client_update/eval executables; without this the accumulated jit cache
    # exhausts host memory mid-sweep (LLVM "Cannot allocate memory")
    jax.clear_caches()

    base = dict(FULL if full else QUICK)
    client = base.pop("client")
    base.update(overrides)   # sweep/caller settings win over the defaults
    if algo == "fedprox":
        client = client._replace(prox_mu=0.1)  # ClientConfig is a NamedTuple
    datasets = [make_dataset(dataset, n_train=base["n_train"],
                             n_val=base["n_val"], n_test=base["n_test"],
                             seed=seed, difficulty=DIFFICULTY)
                for seed in seeds]
    if engine == "scan" and algo != "centralized":
        from repro.grid import GridSpec, run_grid
        cfg = FLConfig(dataset=dataset, selector=algo, client=client,
                       engine="scan", **base)
        out = run_grid(GridSpec.product(cfg, seeds=list(seeds)),
                       data=datasets)
        results = out.results
    else:
        results = []
        for seed, data in zip(seeds, datasets):
            cfg = FLConfig(dataset=dataset, selector=algo, seed=seed,
                           client=client, engine=engine
                           if algo != "centralized" else "loop", **base)
            if algo == "centralized":
                results.append(run_centralized(cfg, data=data))
            else:
                results.append(run_federated(cfg, data=data))
    accs = [r.final_acc for r in results]
    walls = [r.wall_time_s for r in results]
    evals = [r.shapley_evals for r in results]
    res = results[-1]
    return {
        "algo": algo,
        "acc_mean": float(np.mean(accs)),
        "acc_std": float(np.std(accs)),
        "wall_s": float(np.mean(walls)),
        "shapley_evals": float(np.mean(evals)),
        "curves": res.test_acc,
        "upload_bytes": getattr(res, "upload_bytes", 0),
        "download_bytes": getattr(res, "download_bytes", 0),
    }


def sweep(setting_name: str, values, algos=None, *, dataset="mnist",
          seeds=(0, 1), full=False, engine="loop", **fixed):
    """Run a table: one column per value of `setting_name`."""
    algos = algos or ALGOS
    rows = []
    for algo in algos + ["centralized"]:
        row = {"algo": algo}
        for v in values:
            t0 = time.time()
            out = run_algo(algo, dataset=dataset, seeds=seeds, full=full,
                           engine=engine, **fixed, **{setting_name: v})
            row[str(v)] = (out["acc_mean"], out["acc_std"])
            row.setdefault("wall_s", 0.0)
            row["wall_s"] += time.time() - t0
        rows.append(row)
    return rows


def print_table(title: str, rows, values) -> None:
    print(f"\n# {title}")
    header = "algo," + ",".join(f"{v}_mean,{v}_std" for v in map(str, values))
    print(header)
    for row in rows:
        cells = [row["algo"]]
        for v in map(str, values):
            m, s = row[v]
            cells += [f"{100*m:.2f}", f"{100*s:.2f}"]
        print(",".join(cells))

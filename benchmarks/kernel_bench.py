"""Micro-benchmarks: us_per_call for the Shapley hot-path implementations.

On this CPU container the Pallas kernels run in interpret mode (orders of
magnitude slower than compiled TPU code), so wall-times are reported for
the jit'd pure-jnp paths; the kernel's *per-call utility-eval savings*
(serial GTG vs batched GTG) is the derived metric that transfers to TPU.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.aggregation import tree_stack
from repro.core.shapley import gtg_shapley
from repro.core.shapley_batched import gtg_shapley_batched
from repro.kernels.ce_loss.ref import ce_loss_ref
from repro.kernels.weighted_avg.ref import weighted_avg_ref


def _time(fn, *args, reps=20) -> float:
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def run() -> list[str]:
    key = jax.random.key(0)
    rows = []

    # weighted averaging: per-subset vs batched (R subsets amortised)
    m, d, r = 10, 1 << 20, 64
    stacked = jax.random.normal(key, (m, d))
    weights = jax.random.dirichlet(key, jnp.ones(m), (r,))
    one = jax.jit(lambda s, w: jnp.einsum("m,md->d", w, s))
    batched = jax.jit(weighted_avg_ref)
    t_one = _time(one, stacked, weights[0])
    t_batch = _time(batched, stacked, weights)
    rows.append(f"weighted_avg_single_8MB,{t_one:.1f},R=1")
    rows.append(f"weighted_avg_batched_8MB,{t_batch:.1f},"
                f"amortised_x{r * t_one / t_batch:.1f}_over_{r}_subsets")

    # fused CE utility
    lg = jax.random.normal(key, (512, 8192))
    lb = jax.random.randint(key, (512,), 0, 8192)
    t_ce = _time(jax.jit(lambda a, b: jnp.mean(ce_loss_ref(a, b))), lg, lb)
    rows.append(f"ce_loss_512x8192,{t_ce:.1f},utility_eval")

    # GTG serial vs batched (utility-evals per round)
    m = 8
    clients = [{"w": jax.random.normal(jax.random.key(i), (256,))}
               for i in range(m)]
    stacked = tree_stack(clients)
    n_k = jnp.arange(1.0, m + 1.0)
    w_prev = {"w": jnp.zeros(256)}
    tgt = jax.random.normal(key, (256,))
    util = lambda p: -jnp.sum((p["w"] - tgt) ** 2)

    t0 = time.perf_counter()
    _, st = gtg_shapley(stacked, n_k, w_prev, util, key, max_iters=50)
    jax.block_until_ready(st.v0)
    t_serial = (time.perf_counter() - t0) * 1e6
    rows.append(f"gtg_serial_M8,{t_serial:.1f},evals={int(st.utility_evals)}")

    t0 = time.perf_counter()
    _, st2 = gtg_shapley_batched(stacked, n_k, w_prev, util,
                                 jax.vmap(util), key, n_perms=50,
                                 use_kernel=False)
    jax.block_until_ready(st2.v0)
    t_b = (time.perf_counter() - t0) * 1e6
    rows.append(f"gtg_batched_M8,{t_b:.1f},evals={int(st2.utility_evals)}")

    # cohort ClientUpdate: M sequential dispatches vs one vmapped dispatch
    from repro.engine.batch_client import jit_batched_client_update
    from repro.federated.client import ClientConfig, client_update
    from repro.models.mlp_cnn import make_mlp

    mdl = make_mlp(input_dim=64, hidden=(64,), n_classes=10)
    ccfg = ClientConfig(epochs=2, batches_per_epoch=2, batch_size=16)
    m_sel, cap = 10, 64
    params = mdl.init(key)
    xs = jax.random.normal(key, (m_sel, cap, 64))
    ys = jax.random.randint(key, (m_sel, cap), 0, 10)
    nv = jnp.full((m_sel,), cap)
    ek = jnp.full((m_sel,), ccfg.epochs)
    sg = jnp.zeros((m_sel,))
    keys = jax.random.split(key, m_sel)

    def seq(p):
        # return ALL outputs so _time's block_until_ready waits for every
        # dispatch, not just the last (PJRT overlaps independent programs)
        return [client_update(mdl, ccfg, p, xs[i], ys[i], nv[i], ek[i],
                              sg[i], keys[i]) for i in range(m_sel)]

    t_seq = _time(seq, params, reps=5)
    t_vmap = _time(lambda p: jit_batched_client_update(
        mdl, ccfg, p, xs, ys, nv, ek, sg, keys), params, reps=5)
    rows.append(f"client_update_seq_M10,{t_seq:.1f},dispatches=10")
    rows.append(f"client_update_vmap_M10,{t_vmap:.1f},"
                f"dispatches=1_speedup_x{t_seq / max(t_vmap, 1e-9):.1f}")
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)

"""Paper Table II: accuracy under communication-round budgets T."""
from benchmarks.fl_common import print_table, sweep

VALUES = [15, 25, 40]
VALUES_FULL = [150, 250, 350]


def run(*, full=False, seeds=(0, 1), dataset="mnist", engine="loop"):
    vals = VALUES_FULL if full else VALUES
    rows = sweep("rounds", vals, dataset=dataset, seeds=seeds, full=full,
                 engine=engine)
    print_table("Table II — timing constraints (T)", rows, vals)
    return rows


if __name__ == "__main__":
    run()

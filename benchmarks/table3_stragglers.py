"""Paper Table III: accuracy with straggler fraction x (partial E_k epochs)."""
from benchmarks.fl_common import print_table, sweep

VALUES = [0.0, 0.5, 0.9]


def run(*, full=False, seeds=(0, 1), dataset="mnist", engine="loop"):
    rows = sweep("straggler_frac", VALUES, dataset=dataset, seeds=seeds,
                 full=full, engine=engine)
    print_table("Table III — systems heterogeneity (straggler fraction)",
                rows, VALUES)
    return rows


if __name__ == "__main__":
    run()

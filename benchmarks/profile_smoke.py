"""Profile smoke: cost cards + capture window on real engine runs (§17).

    PYTHONPATH=src python -m benchmarks.profile_smoke  (or `make profile-smoke`)

Drives one tiny telemetry-on scan run and one tiny segmented grid with a
profiler capture window open, then asserts the §17 observability contract
end-to-end:

  * every `compile` event in both streams carries a populated cost card
    (flops, bytes accessed, per-device peak bytes, roofline terms);
  * the `profile` event reports a real capture (`captured=True` on
    backends where `jax.profiler.start_trace` works, host-span fallback
    otherwise) with per-stage wall seconds recovered from the trace;
  * both streams schema-validate.

Exit nonzero on any violation — `CHECK_PROFILE=1 scripts/check.sh` turns
this into a gate.  No BENCH artifact: this is a contract smoke, not a
timing bench (BENCH_telemetry.json owns the overhead numbers).
"""
from __future__ import annotations

import os
import sys
import tempfile

from repro.federated.client import ClientConfig
from repro.federated.server import FLConfig, run_federated
from repro.grid import GridSpec, run_grid
from repro.telemetry import Telemetry, validate_events

TINY = dict(n_clients=8, m=3, rounds=4, n_train=400, n_val=80, n_test=80,
            eval_every=2,
            client=ClientConfig(epochs=1, batches_per_epoch=2,
                                batch_size=16))

CARD_KEYS = ("flops", "bytes_accessed", "peak_bytes",
             "intensity_flops_per_byte", "roofline")


def _check_cards(events, who: str) -> list[str]:
    errors = []
    compiles = [e for e in events if e["event"] == "compile"]
    if not compiles:
        errors.append(f"{who}: no compile events in stream")
    for ev in compiles:
        card = ev.get("cost_card")
        if not card:
            errors.append(f"{who}: compile event {ev.get('program')!r} "
                          "has no cost card")
            continue
        missing = [k for k in CARD_KEYS if card.get(k) is None]
        if missing:
            errors.append(f"{who}: {ev.get('program')!r} card missing "
                          f"{missing}")
    profiles = [e for e in events if e["event"] == "profile"]
    if not profiles:
        errors.append(f"{who}: no profile event (capture window absent)")
    for ev in profiles:
        if not ev.get("stage_wall_s"):
            errors.append(f"{who}: profile event has no stage walls")
    return errors


def main() -> int:
    errors: list[str] = []
    with tempfile.TemporaryDirectory() as td:
        print("== scan run (telemetry + capture window) ==")
        cfg = FLConfig(engine="scan", selector="greedyfed", **TINY)
        tel = Telemetry(trace_dir=os.path.join(td, "scan"),
                        heartbeat_every_s=1e9)
        run_federated(cfg, telemetry=tel)
        validate_events(tel.events)
        errors += _check_cards(tel.events, "scan")

        print("== segmented grid (telemetry + capture window) ==")
        base = FLConfig(engine="scan", selector="greedyfed", **TINY)
        gspec = GridSpec.product(base, selectors=["greedyfed", "fedavg"],
                                 seeds=[0])
        gtel = Telemetry(trace_dir=os.path.join(td, "grid"),
                        heartbeat_every_s=1e9)
        run_grid(gspec, rounds_per_segment=2, telemetry=gtel)
        validate_events(gtel.events)
        errors += _check_cards(gtel.events, "grid")

        for tel_, who in ((tel, "scan"), (gtel, "grid")):
            for ev in tel_.events:
                if ev["event"] == "compile" and ev.get("cost_card"):
                    c = ev["cost_card"]
                    print(f"  {who}:{ev['program']}: "
                          f"{c['flops']:.3g} flops, "
                          f"{c['bytes_accessed']:.3g} B accessed, "
                          f"peak {c['peak_bytes'] / 1e6:.1f} MB/dev, "
                          f"{c['intensity_flops_per_byte']:.2f} flops/B "
                          f"({c['roofline']['dominant']}-bound)")
                elif ev["event"] == "profile":
                    walls = ", ".join(f"{k}={v:.2f}s" for k, v in
                                      sorted(ev["stage_wall_s"].items()))
                    print(f"  {who}:profile captured={ev['captured']} "
                          f"source={ev['source']} [{walls}]")

    if errors:
        print("\nPROFILE SMOKE FAILED:", file=sys.stderr)
        for e in errors:
            print(f"  - {e}", file=sys.stderr)
        return 1
    print("profile smoke OK: every compile event carries a cost card; "
          "capture window recovered stage walls")
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""Communication-efficiency ledger: the paper's title claim, in bytes.

Selection (GreedyFed) and compression (quant8/topk) are orthogonal ways to
cut client<->PS traffic; this benchmark measures accuracy x total upload
bytes for each and for the combination, on the same data/seeds.

    PYTHONPATH=src python -m benchmarks.comm_efficiency

(opt-in: not part of the default `benchmarks.run` table sweep)
"""
from __future__ import annotations

from benchmarks.fl_common import run_algo

SETTINGS = [
    ("fedavg", "identity"),
    ("fedavg", "quant8"),
    ("fedavg", "quant8_topk"),
    ("greedyfed", "identity"),
    ("greedyfed", "quant8"),
    ("greedyfed_dropout", "quant8"),
]


def run(*, seeds=(0,), full=False):
    print("\n# communication efficiency "
          "(algo,codec,acc,upload_MB,download_MB,acc_per_upload_GB)")
    rows = []
    for algo, codec in SETTINGS:
        out = run_algo(algo, seeds=seeds, full=full, upload_codec=codec,
                       privacy_sigma=0.05)  # heterogeneous regime
        up = out.get("upload_bytes", 0) / 2**20
        down = out.get("download_bytes", 0) / 2**20
        eff = out["acc_mean"] / max(up / 1024, 1e-9)
        print(f"{algo},{codec},{out['acc_mean']:.4f},{up:.1f},{down:.1f},"
              f"{eff:.2f}")
        rows.append((algo, codec, out["acc_mean"], up, down))
    return rows


if __name__ == "__main__":
    run()

"""Communication-efficiency ledger: the paper's title claim, in bytes.

Selection (GreedyFed) and compression (quant8/topk) are orthogonal ways to
cut client<->PS traffic; this benchmark measures the joint Pareto frontier
— accuracy x total upload bytes x rounds-to-target-accuracy — for every
(strategy, codec) cell on the same data/seeds.

Since the §18 codec-partition lift the whole sweep is ONE `run_grid`
call: `upload_codec` joined the partition key, so a strategies x codecs
grid compiles one executable per (capability, codec) partition and
dispatches once per partition, instead of the v1 bench's serial
`run_algo` loop (one full setup + compile + dispatch per setting).  The
artifact records that collapse (`grid.serial_runs_replaced` vs
`grid.dispatches`) next to the frontier.

A second section microbenchmarks the codec roundtrip itself at the
benchmark's model shapes: the fused `kernels.delta_codec` path the scan
engine now runs (one pass over the cohort-stacked delta) against the
legacy per-leaf tree-map chain (`compression.codec_roundtrip` under
vmap), as compiled flops / bytes accessed (§17 cost cards) and wall
latency.

    PYTHONPATH=src python -m benchmarks.comm_efficiency --json BENCH_comm.json

(opt-in: not part of the default `benchmarks.run` table sweep; `--json`
— or `make bench-comm` — additionally writes the provenance-stamped
BENCH_comm.json ledger via telemetry's one bench writer.  Gate it in CI
with `CHECK_BENCH_COMM=1 scripts/check.sh`.)
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from benchmarks.fl_common import DIFFICULTY, FULL, QUICK
from repro.data.synth import make_dataset
from repro.federated.server import FLConfig
from repro.grid import GridCell, GridSpec, run_grid
from repro.telemetry import write_bench_json

STRATEGIES = ["fedavg", "greedyfed", "greedyfed_dropout"]
CODECS = ["identity", "quant8", "topk", "quant8_topk"]
PRIVACY_SIGMA = 0.05       # heterogeneous regime (matches bench v1)
TARGET_FRAC = 0.95         # rounds-to-target: 95% of the best identity acc


def _rounds_to_target(curve, target: float):
    """First (1-based) round whose eval accuracy reaches `target`."""
    for t, acc in curve:
        if acc >= target:
            return int(t) + 1
    return None


def _pareto_rows(spec: GridSpec, grid, seeds) -> tuple:
    """Aggregate the grid's cells into one frontier row per (algo, codec),
    seed-meaned, with rounds/bytes-to-target against the shared target."""
    by_setting: dict = {}
    for cell, res in zip(spec.cells, grid.results):
        codec = dict(cell.overrides).get("upload_codec", "identity")
        by_setting.setdefault((cell.selector, codec), []).append(res)
    # the target is relative to the best UNCOMPRESSED final accuracy, so
    # every codec is judged against the same accuracy bar
    best_identity = max(
        float(np.mean([r.final_acc for r in results]))
        for (_, codec), results in by_setting.items() if codec == "identity")
    target = TARGET_FRAC * best_identity
    rows = []
    for (algo, codec), results in by_setting.items():
        accs = [r.final_acc for r in results]
        up = int(np.mean([r.upload_bytes for r in results]))
        down = int(np.mean([r.download_bytes for r in results]))
        rtts = [_rounds_to_target(r.test_acc, target) for r in results]
        rtt = (float(np.mean([t for t in rtts if t is not None]))
               if all(t is not None for t in rtts) else None)
        rounds = results[0].config.rounds
        rows.append({
            "algo": algo, "codec": codec,
            "acc_mean": float(np.mean(accs)), "acc_std": float(np.std(accs)),
            "upload_bytes": up, "download_bytes": down,
            "rounds_to_target": rtt,
            # uploads are charged per granted cohort, uniform per round
            # in-protocol, so bytes-to-target scales linearly in rounds
            "bytes_to_target":
                int(up * rtt / rounds) if rtt is not None else None,
            "acc_per_upload_gb":
                float(np.mean(accs)) / max(up / 2**30, 1e-9),
        })
    return rows, target


def _stacked_delta_inputs(cfg: FLConfig, data):
    """(stacked cohort params, reference params) at the bench model shapes."""
    import jax.numpy as jnp

    from repro.federated.server import setup_run

    setup = setup_run(cfg, data)
    key = jax.random.key(17)
    keys = jax.random.split(key, len(jax.tree.leaves(setup.params)))
    it = iter(keys)
    stacked = jax.tree.map(
        lambda p: p[None] + 1e-2 * jax.random.normal(
            next(it), (cfg.m,) + p.shape, p.dtype), setup.params)
    return stacked, setup.params


def _time_us(fn, *args, repeats: int = 20) -> float:
    jax.block_until_ready(fn(*args))          # warmup / compile
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / repeats * 1e6


def codec_roundtrip_microbench(cfg: FLConfig, data) -> dict:
    """Fused delta-codec path vs legacy per-leaf tree-map chain: compiled
    flops / bytes accessed (§17 cost cards) and wall latency, per codec,
    at the benchmark's (m, model) shapes."""
    from repro.federated.compression import codec_roundtrip
    from repro.kernels.delta_codec import delta_codec_roundtrip
    from repro.telemetry.profile import cost_card

    stacked, params = _stacked_delta_inputs(cfg, data)
    out: dict = {}
    for codec in CODECS:
        if codec == "identity":
            continue
        fused = jax.jit(
            lambda s, p, c=codec: delta_codec_roundtrip(s, p, c))
        legacy = jax.jit(lambda s, p, c=codec: jax.vmap(
            lambda w: codec_roundtrip(c, w, p))(s))
        row: dict = {}
        for name, fn in (("fused", fused), ("ref_tree_map", legacy)):
            card = cost_card(fn, stacked, params) or {}
            row[name] = {
                "flops": card.get("flops"),
                "bytes_accessed": card.get("bytes_accessed"),
                "peak_bytes": card.get("peak_bytes"),
                "latency_us": _time_us(fn, stacked, params),
            }
        for metric in ("flops", "bytes_accessed"):
            a, b = row["fused"][metric], row["ref_tree_map"][metric]
            if a and b:
                row[f"ref_over_fused_{metric}"] = b / a
        row["speedup_fused_vs_ref"] = (
            row["ref_tree_map"]["latency_us"] / row["fused"]["latency_us"])
        out[codec] = row
    return out


def run(*, seeds=(0,), full=False, json_path=None):
    base_kw = dict(FULL if full else QUICK)
    client = base_kw.pop("client")
    base = FLConfig(dataset="mnist", selector=STRATEGIES[0], client=client,
                    engine="scan", privacy_sigma=PRIVACY_SIGMA, **base_kw)
    datasets = {seed: make_dataset(
        "mnist", n_train=base.n_train, n_val=base.n_val, n_test=base.n_test,
        seed=seed, difficulty=DIFFICULTY) for seed in seeds}

    # the whole strategies x codecs frontier as ONE partitioned grid call
    cells, cell_data = [], []
    for algo in STRATEGIES:
        for codec in CODECS:
            for seed in seeds:
                cells.append(GridCell(algo, seed,
                                      overrides={"upload_codec": codec}))
                cell_data.append(datasets[seed])
    spec = GridSpec(base, tuple(cells))
    t0 = time.perf_counter()
    grid = run_grid(spec, data=cell_data)
    grid_wall = time.perf_counter() - t0

    rows, target = _pareto_rows(spec, grid, seeds)
    print("\n# communication-efficiency Pareto frontier "
          f"(target acc {target:.4f})")
    print("algo,codec,acc,upload_MB,rounds_to_target,acc_per_upload_GB")
    for r in rows:
        rtt = "-" if r["rounds_to_target"] is None else \
            f"{r['rounds_to_target']:.0f}"
        print(f"{r['algo']},{r['codec']},{r['acc_mean']:.4f},"
              f"{r['upload_bytes'] / 2**20:.1f},{rtt},"
              f"{r['acc_per_upload_gb']:.2f}")

    grid_stats = {
        "cells": len(cells),
        "partitions": len(grid.partitions),
        "executables": len(grid.partitions),
        "dispatches": grid.dispatches,
        "serial_runs_replaced": len(cells),
        "partition_labels": [p.label for p in grid.partitions],
        "partition_codecs": [p.upload_codec for p in grid.partitions],
        "wall_s": grid_wall,
    }
    print(f"# grid: {grid_stats['cells']} cells -> "
          f"{grid_stats['executables']} executables, "
          f"{grid_stats['dispatches']} dispatches "
          f"(v1 ran {grid_stats['serial_runs_replaced']} serial runs)")

    micro = codec_roundtrip_microbench(base, datasets[seeds[0]])
    print("# codec_roundtrip fused-vs-tree-map "
          "(codec,fused_us,ref_us,bytes_ratio)")
    for codec, row in micro.items():
        br = row.get("ref_over_fused_bytes_accessed")
        print(f"{codec},{row['fused']['latency_us']:.0f},"
              f"{row['ref_tree_map']['latency_us']:.0f},"
              + (f"{br:.2f}" if br else "-"))

    if json_path:
        write_bench_json(json_path, {
            "schema": "bench_comm/v2",
            "seeds": list(seeds), "full": full,
            "privacy_sigma": PRIVACY_SIGMA,
            "target_frac": TARGET_FRAC, "target_acc": target,
            "pareto": rows,
            "grid": grid_stats,
            "codec_roundtrip": micro,
        })
        print(f"json_report,{json_path}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--full", action="store_true",
                    help="paper-scale shapes instead of the smoke config")
    ap.add_argument("--json", default=None,
                    help="write the provenance-stamped BENCH_comm.json")
    a = ap.parse_args()
    run(full=a.full, json_path=a.json)

"""Communication-efficiency ledger: the paper's title claim, in bytes.

Selection (GreedyFed) and compression (quant8/topk) are orthogonal ways to
cut client<->PS traffic; this benchmark measures accuracy x total upload
bytes for each and for the combination, on the same data/seeds.

    PYTHONPATH=src python -m benchmarks.comm_efficiency --json BENCH_comm.json

(opt-in: not part of the default `benchmarks.run` table sweep; `--json`
— or `make bench-comm` — additionally writes the provenance-stamped
BENCH_comm.json ledger via telemetry's one bench writer)
"""
from __future__ import annotations

import argparse

from benchmarks.fl_common import run_algo
from repro.telemetry import write_bench_json

SETTINGS = [
    ("fedavg", "identity"),
    ("fedavg", "quant8"),
    ("fedavg", "quant8_topk"),
    ("greedyfed", "identity"),
    ("greedyfed", "quant8"),
    ("greedyfed_dropout", "quant8"),
]


def run(*, seeds=(0,), full=False, json_path=None):
    print("\n# communication efficiency "
          "(algo,codec,acc,upload_MB,download_MB,acc_per_upload_GB)")
    rows, cells = [], []
    for algo, codec in SETTINGS:
        out = run_algo(algo, seeds=seeds, full=full, upload_codec=codec,
                       privacy_sigma=0.05)  # heterogeneous regime
        up = out.get("upload_bytes", 0) / 2**20
        down = out.get("download_bytes", 0) / 2**20
        eff = out["acc_mean"] / max(up / 1024, 1e-9)
        print(f"{algo},{codec},{out['acc_mean']:.4f},{up:.1f},{down:.1f},"
              f"{eff:.2f}")
        rows.append((algo, codec, out["acc_mean"], up, down))
        cells.append({
            "algo": algo, "codec": codec,
            "acc_mean": out["acc_mean"],
            "acc_std": out.get("acc_std"),
            "upload_bytes": out.get("upload_bytes", 0),
            "download_bytes": out.get("download_bytes", 0),
            "acc_per_upload_gb": eff,
        })
    if json_path:
        write_bench_json(json_path, {
            "schema": "bench_comm/v1",
            "seeds": list(seeds), "full": full,
            "privacy_sigma": 0.05,
            "settings": cells,
        })
        print(f"json_report,{json_path}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--full", action="store_true",
                    help="paper-scale shapes instead of the smoke config")
    ap.add_argument("--json", default=None,
                    help="write the provenance-stamped BENCH_comm.json")
    a = ap.parse_args()
    run(full=a.full, json_path=a.json)

"""Client-axis scaling bench (DESIGN.md §16): per-device client state.

With `clients_shards = C` the padded per-client stacks (data, n_valid,
sigma, straggler tables, selector vectors) shard over the "clients" mesh
axis instead of replicating, so per-device footprint drops from O(N) to
O(N/C + M*D).  This bench measures that claim on the forced-host 8-device
debug mesh: for N in {300, 3k, 30k} it records the measured per-device
client-state bytes (summed over each device's addressable shards) and the
warm per-round latency, dense vs sharded.  Dense is only *run* up to
N=3000 — at N=30k its footprint is reported arithmetically (every byte on
one device), which is the point: the sharded run completes with ~C x less
state per device.

A `memory_analysis` block additionally records the XLA compiled-peak-bytes
probe (repro.launch.compat.compiled_memory_stats) of the dense vs sharded
segment step via a 1-cell grid at the smallest N.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
    PYTHONPATH=src python -m benchmarks.client_scale --json BENCH_clients.json

(`make client-scale-smoke` runs the N=300 subset; opt into the check gate
with CHECK_CLIENT_SCALE=1 ./scripts/check.sh)
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.data.synth import make_dataset
from repro.federated.client import ClientConfig
from repro.federated.server import FLConfig, run_federated, setup_run
from repro.launch.mesh import make_run_mesh
from repro.telemetry import write_bench_json

SHARDS = 8
ROUNDS = 3
N_FULL = (300, 3_000, 30_000)
N_SMOKE = (300,)
DENSE_RUN_MAX = 3_000    # beyond this, dense is reported, not executed


def _cfg(n: int, shards: int) -> FLConfig:
    # selection without SV (fedavg -> random): the bench isolates the
    # client-state axis, not the valuation path
    return FLConfig(
        n_clients=n, m=10, rounds=ROUNDS, selector="fedavg", engine="scan",
        eval_every=1000, n_train=2 * n, n_val=120, n_test=120,
        dirichlet_alpha=100.0,
        client=ClientConfig(epochs=1, batches_per_epoch=2, batch_size=8),
        clients_shards=shards)


def _state_bytes(cfg: FLConfig, data) -> tuple[int, int, tuple]:
    """(max-per-device bytes, global bytes, xs shape) of the client-state
    stacks exactly as `setup_run` places them (lazy shard callbacks under
    a client mesh, single-device stacks otherwise)."""
    mesh = (make_run_mesh(1, cfg.clients_shards)
            if cfg.clients_shards > 1 else None)
    s = setup_run(cfg, data, client_mesh=mesh)
    per: dict = {}
    total = 0
    for a in (s.xs, s.ys, s.n_valid):
        total += int(np.prod(a.shape)) * a.dtype.itemsize
        for sh in a.addressable_shards:
            per[sh.device.id] = per.get(sh.device.id, 0) + sh.data.nbytes
    return max(per.values()), total, tuple(s.xs.shape)


def _dense_bytes_arith(xs_shape: tuple, n: int) -> int:
    """Dense footprint from the sharded shapes: (N, cap, dim) f32 +
    (N, cap) i32 labels + (N,) i32 counts, all on ONE device."""
    cap = xs_shape[1]
    dim = int(np.prod(xs_shape[2:]))
    return n * cap * dim * 4 + n * cap * 4 + n * 4


def _timed_run(cfg: FLConfig, data) -> float:
    """Warm per-round seconds: two runs (the second reuses every cached
    executable), min of execute_time_s over rounds."""
    times = [run_federated(cfg, data).execute_time_s for _ in range(2)]
    return min(times) / cfg.rounds


def _memory_analysis(n: int, data) -> dict:
    """Compiled-peak probe (1-cell grid, compile_stats=True): XLA
    memory_analysis() of the dense vs client-sharded segment step."""
    from repro.grid.runner import run_grid
    from repro.grid.spec import GridSpec

    out = {"n_clients": n}
    for label, shards, shard in (("dense", 1, False), ("sharded", SHARDS,
                                                       True)):
        g = run_grid(GridSpec.product(_cfg(n, shards), seeds=(0,)),
                     data=data, shard=shard, compile_stats=True)
        out[label] = {"peak_bytes": g.partitions[0].peak_bytes,
                      "flops_per_dispatch":
                          None if g.partitions[0].flops_per_dispatch
                          != g.partitions[0].flops_per_dispatch
                          else g.partitions[0].flops_per_dispatch}
    return out


def run(*, smoke: bool = False, json_path: str | None = None) -> dict:
    if jax.device_count() < SHARDS:
        raise SystemExit(
            f"client_scale needs {SHARDS} devices; run under "
            "XLA_FLAGS=--xla_force_host_platform_device_count=8 "
            "(see `make client-scale-smoke`)")

    n_list = N_SMOKE if smoke else N_FULL
    print("\n# client-axis scaling "
          "(n,path,ran,per_device_MB,total_MB,round_latency_s)")
    rows = []
    for n in n_list:
        cfg_d, cfg_s = _cfg(n, 1), _cfg(n, SHARDS)
        data = make_dataset(cfg_d.dataset, n_train=cfg_d.n_train,
                            n_val=cfg_d.n_val, n_test=cfg_d.n_test,
                            seed=cfg_d.seed)
        sh_dev, sh_total, xs_shape = _state_bytes(cfg_s, data)
        sharded = {"ran": True, "per_device_state_bytes": sh_dev,
                   "total_state_bytes": sh_total,
                   "pad_rows": xs_shape[0] - n,
                   "round_latency_s": _timed_run(cfg_s, data)}

        dense_total = _dense_bytes_arith(xs_shape, n)
        dense = {"ran": n <= DENSE_RUN_MAX,
                 "per_device_state_bytes": dense_total,
                 "total_state_bytes": dense_total, "round_latency_s": None}
        if dense["ran"]:
            d_dev, d_total, _ = _state_bytes(cfg_d, data)
            dense.update(per_device_state_bytes=d_dev,
                         total_state_bytes=d_total,
                         round_latency_s=_timed_run(cfg_d, data))

        row = {"n_clients": n, "cap": xs_shape[1], "dense": dense,
               "sharded": sharded,
               "dense_over_sharded_per_device_bytes":
                   dense["per_device_state_bytes"] / max(sh_dev, 1)}
        rows.append(row)
        for label, r in (("dense", dense), ("sharded", sharded)):
            lat = r["round_latency_s"]
            print(f"{n},{label},{r['ran']},"
                  f"{r['per_device_state_bytes'] / 2**20:.2f},"
                  f"{r['total_state_bytes'] / 2**20:.2f},"
                  f"{'-' if lat is None else f'{lat:.4f}'}")

    report = {
        "schema": "bench_clients/v1",
        "devices": jax.device_count(),
        "clients_shards": SHARDS,
        "rounds": ROUNDS,
        "smoke": smoke,
        "rows": rows,
        "memory_analysis": _memory_analysis(n_list[0], None),
    }
    if json_path:
        write_bench_json(json_path, report)
        print(f"json_report,{json_path}")
    return report


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="N=300 subset for the scripts/check.sh gate")
    ap.add_argument("--json", default=None,
                    help="write BENCH_clients.json via telemetry's "
                         "provenance-stamping writer")
    a = ap.parse_args()
    run(smoke=a.smoke, json_path=a.json)

"""Round/run-engine benchmark: loop vs batched vs whole-run scan.

Three execution tiers (parity-pinned by tests/test_engine.py):

  * loop    — the legacy per-client python loop: M+1 dispatches per round;
  * batched — `round_step`: ONE dispatch per round with donated params;
  * scan    — `run_scan`: the WHOLE T-round run (device-resident selection
              and valuation included) as one `lax.scan` dispatch.

Measurements:

  * round latency — time for ONE round's result to materialise (blocking).
    This is what every SV-driven strategy pays: GreedyFed/UCB/S-FedAvg
    consume the round's Shapley values before the next selection, so the
    round chain can never pipeline.  (A pure-random selector never reads
    round outputs, letting the PJRT CPU runtime overlap the loop's
    independent client programs across rounds — a throughput artifact no
    paper workload can exploit.)

  * end-to-end greedyfed — steady-state seconds/round: for loop/batched,
    the min-of-reps difference between warm runs at T and 3T (setup,
    compile, and per-run wall noise cancel); for scan, the cached
    whole-run executable timed directly (setup noise swamps its T-vs-3T
    difference).  The dispatch counts are the load-bearing comparison.

Plus multi-seed amortisation (`run_federated_replicated`, per-round and
whole-run flavours) and a virtual-clock deadline sweep (DESIGN.md §9).

`run(json_path=...)` (or `make bench-smoke`) additionally writes
BENCH_selection.json — machine-readable dispatch counts and latencies so
the selection-path perf trajectory is tracked across PRs.  `--grid`
(`make grid-smoke`) exercises the partitioned/segmented/sharded grid
runner into BENCH_grid.json, and `--shapley` (`make bench-shapley`)
benches the dense vs streaming device GTG-Shapley paths (DESIGN.md §8 vs
§14) into BENCH_shapley.json.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregation import (
    normalized_weights, tree_stack, weighted_average,
)
from repro.engine.round_engine import RoundEngine, RoundSpec
from repro.engine.schedule import ScheduleConfig
from repro.federated.client import ClientConfig, client_update
from repro.federated.server import (
    FLConfig, run_federated, run_federated_replicated, setup_run,
)
from repro.telemetry import write_bench_json


def _write_report(json_path: str | None, report: dict,
                  rows: list[str]) -> None:
    """Every BENCH_*.json goes through the one provenance-stamping
    writer (repro.telemetry.events.write_bench_json)."""
    if json_path:
        write_bench_json(json_path, report)
        rows.append(f"json_report,0,{json_path}")

# acceptance config: M=10 of N=50 clients per round
BASE = dict(
    n_clients=50, m=10, n_train=2500, n_val=300, n_test=300,
    eval_every=1000,   # keep eval dispatches out of the round timing
    client=ClientConfig(epochs=3, batches_per_epoch=3, batch_size=32),
)
# CI-smoke config: same shape, small enough for scripts/check.sh
SMOKE = dict(
    n_clients=16, m=4, n_train=800, n_val=120, n_test=120,
    eval_every=1000,
    client=ClientConfig(epochs=2, batches_per_epoch=2, batch_size=32),
)
R_SHORT, R_LONG = 2, 10


def _timeit_chain(fn, params, reps=10) -> float:
    """Time `params = fn(params)` chained, as the server consumes it.

    Chaining keeps the measurement donation-safe on accelerators (the
    fused step donates its params buffer, so re-calling with the same
    pytree would fail there) and blocks each call on the previous round.
    """
    p = jax.block_until_ready(fn(params))  # compile + warm
    t0 = time.perf_counter()
    for _ in range(reps):
        p = fn(p)
    jax.block_until_ready(p)
    return (time.perf_counter() - t0) / reps


def _round_latency_rows(base: dict) -> tuple[list[str], dict, float]:
    cfg = FLConfig(**base)
    s = setup_run(cfg)
    sel = np.arange(cfg.m)
    epochs_k = np.full(cfg.m, cfg.client.epochs, np.int32)
    key = jax.random.key(1)
    tag = f"N{cfg.n_clients}_M{cfg.m}"

    def loop_round(params):
        # the legacy engine's round body, verbatim shape (M+1 dispatches)
        ckeys = jax.random.split(key, cfg.m + 1)
        ups = [client_update(s.model, cfg.client, params, s.xs[k], s.ys[k],
                             s.n_valid[k], jnp.asarray(int(epochs_k[i])),
                             jnp.asarray(s.sigma_k_all[k]), ckeys[i])
               for i, k in enumerate(sel)]
        stacked = tree_stack(ups)
        n_k = s.n_k_all[jnp.asarray(sel)]
        return weighted_average(stacked, normalized_weights(n_k))

    engine = RoundEngine(s.model, cfg.client, RoundSpec(), s.xs, s.ys,
                         s.n_valid, jnp.asarray(s.sigma_k_all),
                         s.x_val, s.y_val)

    t_loop = _timeit_chain(loop_round, s.params)
    # fresh copy: the fused step donates its params argument on accelerators
    t_fuse = _timeit_chain(
        lambda p: engine.step(p, sel, epochs_k, key).params,
        jax.tree.map(jnp.copy, s.params))
    rows = [
        f"round_latency_loop_{tag},{t_loop * 1e6:.0f},"
        f"dispatches={cfg.m + 1}",
        f"round_latency_batched_{tag},{t_fuse * 1e6:.0f},"
        f"dispatches=1_speedup_x{t_loop / max(t_fuse, 1e-12):.2f}",
    ]
    stats = {"loop": t_loop * 1e6, "batched": t_fuse * 1e6}
    return rows, stats, t_fuse


def _per_round_e2e(cfg: FLConfig, r_long: int,
                   reps: int = 2) -> tuple[float, int, int]:
    """Steady-state (seconds/round, dispatches/round, total dispatches of
    the long run), from the min-of-reps difference between warm runs at
    rounds = r_long and 3*r_long.  Every measured length is warmed first —
    the scan engine compiles one executable per T (cached process-wide),
    so an unwarmed length would leave its compile inside the difference —
    and min-of-reps plus the 3x round gap keeps per-run wall noise (which
    once produced *negative* per-round times here) out of the signal."""
    r_longer = 3 * r_long

    def min_wall(rounds: int):
        res = None
        best = float("inf")
        for i in range(reps + 1):   # first call per length warms compile
            res = run_federated(dataclasses.replace(cfg, rounds=rounds))
            if i > 0:
                best = min(best, res.wall_time_s)
        return best, res

    w_long, long = min_wall(r_long)
    w_longer, longer = min_wall(r_longer)
    dt = (w_longer - w_long) / (r_longer - r_long)
    ddisp = (longer.dispatches - long.dispatches) // (r_longer - r_long)
    return dt, ddisp, long.dispatches


def _scan_steady_state(cfg: FLConfig) -> float:
    """Steady-state seconds/round of the whole-run scan: time the cached
    executable itself (blocking, min-of-reps) and divide by T.  A scan
    run's wall time is dominated by host-side setup (data generation,
    partitioning) whose run-to-run variance exceeds the T-vs-3T compute
    difference on a loaded box, so the run-difference estimator the other
    engines use cannot resolve it (it once reported *negative* µs/round
    here); timing the dispatch directly is the honest number and mirrors
    how a sweep consumes the engine (setup once, dispatch per cell)."""
    from repro.engine.round_engine import jitted_run_scan
    from repro.engine.scan_engine import make_scan_spec, scan_operands

    s = setup_run(cfg)
    spec = make_scan_spec(cfg, (s.sel_spec,))
    run_scan = jitted_run_scan(s.model, cfg.client, spec)
    rest = scan_operands(cfg, s)
    # chained through params (the scan donates its buffer on accelerators)
    # with each rep timed individually so min-of-reps drops load spikes,
    # like every other steady-state estimator in this file
    p = jax.block_until_ready(
        run_scan(jax.tree.map(jnp.copy, s.params), *rest).params)  # warm
    best = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        p = jax.block_until_ready(run_scan(p, *rest).params)
        best = min(best, time.perf_counter() - t0)
    return best / cfg.rounds


def run(*, full: bool = False, smoke: bool = False,
        json_path: str | None = None) -> list[str]:
    base = SMOKE if smoke else BASE
    r_long = 6 if smoke else R_LONG
    tag = f"N{base['n_clients']}_M{base['m']}"
    report: dict = {
        "schema": "bench_selection/v1",
        "backend": jax.default_backend(),
        "mode": "smoke" if smoke else ("full" if full else "quick"),
        "config": {"n_clients": base["n_clients"], "m": base["m"],
                   "rounds_short": R_SHORT, "rounds_long": r_long,
                   "e2e_rounds": [r_long, 3 * r_long]},
    }

    # shared-executable amortisation: the fused step is cached process-wide
    # on (model, client cfg, spec), so every later seed of a table cell
    # skips tracing+compilation entirely.  Must run FIRST (cold cache).
    rcfg0 = FLConfig(engine="batched", selector="fedavg", rounds=R_SHORT,
                     **base)
    cold = run_federated(rcfg0).wall_time_s
    warm = run_federated(dataclasses.replace(rcfg0, seed=1)).wall_time_s
    rows = [
        f"fused_run_cold_compile,{cold * 1e6:.0f},rounds={R_SHORT}",
        f"fused_run_cached_seed1,{warm * 1e6:.0f},"
        f"shared_executable_x{cold / max(warm, 1e-12):.2f}",
    ]

    lat_rows, lat_stats, t_fuse_round = _round_latency_rows(base)
    rows += lat_rows
    report["round_latency_us"] = lat_stats
    shapley_iters = 50 if full else 8

    cfg = dict(base, selector="greedyfed", shapley_max_iters=shapley_iters)
    t_loop, d_loop, _ = _per_round_e2e(FLConfig(engine="loop", **cfg), r_long)
    t_fuse, d_fuse, _ = _per_round_e2e(FLConfig(engine="batched", **cfg),
                                       r_long)
    # the scan's T-vs-3T compute difference sits below per-run setup
    # noise, so its steady state is timed at the dispatch itself; the
    # dispatch count still comes from a real run so a regression out of
    # the one-dispatch contract would show up here
    scan_cfg = FLConfig(engine="scan", rounds=r_long, **cfg)
    scan_total = run_federated(scan_cfg).dispatches
    t_scan = _scan_steady_state(scan_cfg)
    rows.append(f"e2e_loop_greedyfed_{tag},{t_loop * 1e6:.0f},"
                f"dispatches_per_round={d_loop}")
    rows.append(f"e2e_batched_greedyfed_{tag},{t_fuse * 1e6:.0f},"
                f"dispatches_per_round={d_fuse}_"
                f"speedup_x{t_loop / max(t_fuse, 1e-12):.2f}")
    rows.append(f"e2e_scan_greedyfed_{tag},{t_scan * 1e6:.0f},"
                f"dispatches_total={scan_total}_"
                f"speedup_x{t_loop / max(t_scan, 1e-12):.2f}")
    report["e2e_greedyfed"] = {
        "loop": {"us_per_round": t_loop * 1e6,
                 "dispatches_per_round": d_loop},
        "batched": {"us_per_round": t_fuse * 1e6,
                    "dispatches_per_round": d_fuse},
        "scan": {"us_per_round": t_scan * 1e6,
                 "dispatches_per_round": 0,       # amortised: 1 per run
                 "dispatches_total": scan_total},
    }
    report["speedup"] = {
        "batched_vs_loop_round_latency":
            lat_stats["loop"] / max(lat_stats["batched"], 1e-9),
        "batched_vs_loop_e2e": t_loop / max(t_fuse, 1e-12),
        "scan_vs_loop_e2e": t_loop / max(t_scan, 1e-12),
        "scan_vs_batched_e2e": t_fuse / max(t_scan, 1e-12),
    }

    # multi-seed vmap: ONE dispatch advances S replicas (per-round flavour)
    # or S whole runs (scan flavour).  On CPU the batched while-loops
    # undercut raw throughput (vs S solo fused rounds); the dispatch-count
    # reduction is the part that transfers to TPU.
    seeds = (0, 1, 2, 3) if full else (0, 1)
    rcfg = FLConfig(engine="batched", selector="fedavg", **base)
    run_federated_replicated(dataclasses.replace(rcfg, rounds=1), seeds)
    # per-round steady state: ALL measured runs are post-warmup (the
    # vmapped round step is one cached executable regardless of `rounds`),
    # min-of-reps at two run lengths, 3x the round gap of the old
    # short/long pair — the old derivation subtracted a cold-ish short
    # run from the long one, and per-run setup noise (~ms) swamped the
    # ~µs/round signal, yielding a *negative* per-round time.
    r_rep_long = 3 * r_long

    def _min_wall(rounds: int, reps: int = 2) -> float:
        return min(run_federated_replicated(
            dataclasses.replace(rcfg, rounds=rounds), seeds)[0].wall_time_s
            for _ in range(reps))

    w_short = _min_wall(r_long)
    w_long = _min_wall(r_rep_long)
    t_rep = (w_long - w_short) / (r_rep_long - r_long)
    t_solo = t_fuse_round * len(seeds)
    rows.append(f"replicated_{len(seeds)}seeds_per_round,{t_rep * 1e6:.0f},"
                f"dispatches=1_for_{len(seeds)}_replicas_"
                f"solo_{len(seeds)}x={t_solo * 1e6:.0f}us")

    scfg = FLConfig(engine="scan", selector="fedavg", **base)
    grid = run_federated_replicated(
        dataclasses.replace(scfg, rounds=r_long), seeds)
    rows.append(f"replicated_scan_{len(seeds)}seeds_whole_run,"
                f"{grid[0].wall_time_s * 1e6:.0f},"
                f"dispatches={grid[0].dispatches}_for_{len(seeds)}_full_runs")
    report["replicated"] = {
        "seeds": len(seeds),
        "per_round_us": t_rep * 1e6,
        "scan_whole_run_us": grid[0].wall_time_s * 1e6,
        "scan_whole_run_dispatches": grid[0].dispatches,
    }

    # deadline sweep: the scheduler turns tau into an accuracy/time knob
    for tau in (0.05, 0.5, 5.0):
        r = run_federated(dataclasses.replace(
            rcfg, rounds=r_long, eval_every=r_long,
            schedule=ScheduleConfig(deadline_s=tau, epoch_time_mean_s=0.1)))
        rows.append(f"deadline_tau{tau}s,{r.sim_time_s * 1e6:.0f},"
                    f"sim_time_acc={r.final_acc:.3f}")

    _write_report(json_path, report, rows)
    return rows


def run_grid_bench(*, full: bool = False,
                   json_path: str | None = "BENCH_grid.json") -> list[str]:
    """The `make grid-smoke` payload: a 2-partition (greedyfed+fedavg),
    2-segment, 4-replica grid through `repro.grid.run_grid`, sharded over
    the replica mesh (4 of the forced-host 8 devices in CI), emitting
    BENCH_grid.json — per-partition dispatch counts and compiled-flops
    evidence that the non-SV partition no longer traces GTG-Shapley,
    segment latency, bytes resident per partition/device, and a
    mixed-`eval_every` grid row (DESIGN.md §13: per-cell cadences, still
    one dispatch per partition per segment).
    """
    import jax

    from repro.grid import GridCell, GridSpec, run_grid

    base_kw = BASE if full else SMOKE
    rounds, k = (8, 4) if full else (4, 2)
    cfg = FLConfig(selector="greedyfed", engine="scan",
                   shapley_max_iters=(50 if full else 8), rounds=rounds,
                   **base_kw)
    gspec = GridSpec.product(cfg, selectors=["greedyfed", "fedavg"],
                             seeds=(0, 1))

    cold = run_grid(gspec, rounds_per_segment=k, compile_stats=True)
    warm = run_grid(gspec, rounds_per_segment=k)   # executables cached
    n_segments = warm.n_segments
    seg_us = warm.wall_time_s / max(
        sum(p.dispatches for p in warm.partitions), 1) * 1e6

    n_dev = len(jax.devices())
    rows, parts = [], []
    for p in cold.partitions:
        rows.append(
            f"grid_partition_{p.label},{p.dispatches},needs_sv={p.needs_sv}"
            f"_evals={p.shapley_evals}_flops={p.flops_per_dispatch:.0f}")
        parts.append({
            "label": p.label, "cells": list(p.cell_indices),
            "needs_sv": p.needs_sv,
            "uses_local_losses": p.uses_local_losses,
            "n_strategies": p.n_strategies,
            "dispatches": p.dispatches,
            "shapley_evals": p.shapley_evals,
            "bytes_resident": p.bytes_resident,
            "flops_per_dispatch": None
            if p.flops_per_dispatch != p.flops_per_dispatch
            else p.flops_per_dispatch,
            "peak_bytes": p.peak_bytes,
        })
    rows.append(f"grid_segment_latency,{seg_us:.0f},"
                f"segments={n_segments}_cells={len(gspec.cells)}")
    bytes_total = sum(p.bytes_resident for p in cold.partitions)
    shard_dev = min(n_dev, 2)   # 2 replicas per partition
    rows.append(f"grid_bytes_resident,{bytes_total},"
                f"per_device={bytes_total // max(shard_dev, 1)}"
                f"_devices={n_dev}")

    # mixed per-cell eval cadences (DESIGN.md §13): one partition, one
    # dispatch per segment, every replica on its own eval curve
    mixed = GridSpec(cfg, (
        GridCell("fedavg", 0),                                 # base cadence
        GridCell("fedavg", 0, overrides={"eval_every": 1}),    # every round
        GridCell("fedavg", 1, overrides={"eval_every": rounds + 1})))
    mg = run_grid(mixed, rounds_per_segment=k)
    evals_per_cell = [len(r.test_acc) for r in mg.results]
    mg_dispatches = sum(p.dispatches for p in mg.partitions)
    rows.append(f"grid_mixed_eval_cadence,{mg_dispatches},"
                f"cells={len(mixed.cells)}_evals_per_cell="
                f"{'/'.join(map(str, evals_per_cell))}")

    sv = next(p for p in cold.partitions if p.needs_sv)
    plain = next(p for p in cold.partitions if not p.needs_sv)
    report = {
        "schema": "bench_grid/v1",
        "backend": jax.default_backend(),
        "devices": n_dev,
        "grid": {"cells": len(gspec.cells),
                 "selectors": ["greedyfed", "fedavg"], "seeds": [0, 1],
                 "rounds": rounds, "rounds_per_segment": k,
                 "n_segments": n_segments},
        "partitions": parts,
        "mixed_eval_cadence": {
            "cells": len(mixed.cells),
            "eval_every": [c.eval_every for c in mixed.cell_configs()],
            "evals_per_cell": evals_per_cell,
            "dispatches": mg_dispatches,
            "n_segments": mg.n_segments,
        },
        "segment_latency_us": seg_us,
        "bytes_resident_total": bytes_total,
        "bytes_resident_per_device": bytes_total // max(shard_dev, 1),
        "sv_partition_skipped_in_plain": {
            "plain_partition_shapley_evals": plain.shapley_evals,
            "flops_ratio_sv_over_plain": None
            if sv.flops_per_dispatch != sv.flops_per_dispatch
            or plain.flops_per_dispatch != plain.flops_per_dispatch
            else sv.flops_per_dispatch / plain.flops_per_dispatch,
        },
    }
    _write_report(json_path, report, rows)
    return rows


def _timeit_blocking(fn, reps: int = 5) -> float:
    """Seconds per call, post-warmup, blocking on the result each call."""
    jax.block_until_ready(fn())   # compile + warm
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def run_shapley_bench(*, full: bool = False,
                      json_path: str | None = "BENCH_shapley.json"
                      ) -> list[str]:
    """The `make bench-shapley` payload: dense (§8) vs streaming (§14)
    device GTG-Shapley on a representative SV problem — e2e SV latency,
    compiled-flops evidence of the ~M-fold reduction in prefix-model
    construction, and the peak-model-bytes story behind `sv_chunk`.

    The cohort is sized so prefix construction (the part the streaming
    path shrinks) carries a dense-path share comparable to the utility
    evals, as it does at paper scale where M ~ 10-30 clients/round.
    """
    from repro.core.aggregation import tree_stack
    from repro.core.shapley_batched import (
        _draw_perms, gtg_shapley_batched, gtg_shapley_streaming,
        make_batched_mlp_utility, prefix_weight_matrix,
    )
    from repro.kernels.prefix_avg.ops import prefix_avg
    from repro.kernels.weighted_avg.ops import weighted_avg
    from repro.launch.compat import compiled_flops
    from repro.models.mlp_cnn import make_mlp

    m, d_in, hidden, n_val = (32, 128, (256,), 64) if full else \
                             (32, 64, (64,), 16)
    n_perms = 128 if full else 64
    use_kernel = jax.default_backend() == "tpu"

    model = make_mlp(input_dim=d_in, hidden=hidden, n_classes=10)
    stacked = tree_stack([model.init(jax.random.key(i)) for i in range(m)])
    n_k = jnp.arange(1.0, m + 1.0) * 10
    w_prev = model.init(jax.random.key(99))
    kx, ky = jax.random.split(jax.random.key(1234))
    x_val = jax.random.normal(kx, (n_val, d_in))
    y_val = jax.random.randint(ky, (n_val,), 0, 10)

    def utility(p):
        return -model.loss(p, x_val, y_val)

    batched = make_batched_mlp_utility(model, x_val, y_val)
    key = jax.random.key(7)
    d_total = sum(int(x.size) for x in jax.tree.leaves(w_prev))
    kw = dict(eps=1e-9, n_perms=n_perms, use_kernel=use_kernel)

    t_dense = _timeit_blocking(lambda: gtg_shapley_batched(
        stacked, n_k, w_prev, utility, batched, key, **kw)[0])
    # sv_chunk=0 is the engines' default (auto: one walk per step off-TPU,
    # single all-resident pass on TPU); -1 forces the unchunked pass
    t_stream = _timeit_blocking(lambda: gtg_shapley_streaming(
        stacked, n_k, w_prev, utility, batched, key, sv_chunk=0, **kw)[0])
    t_unchunked = _timeit_blocking(lambda: gtg_shapley_streaming(
        stacked, n_k, w_prev, utility, batched, key, sv_chunk=-1, **kw)[0])

    # construction-only compiled flops: the dense (R*M, M) x (M, D)
    # contraction vs the streaming gather + running sum — the ~M-fold
    # FLOP reduction, isolated from the (shared) utility evaluations
    perms = _draw_perms(key, m, n_perms)

    @jax.jit
    def dense_construction(st, p, nk):
        flat_w = prefix_weight_matrix(p, nk).reshape(n_perms * m, m)
        return weighted_avg(st, flat_w, use_kernel=use_kernel)

    @jax.jit
    def stream_construction(st, p, nk):
        return prefix_avg(st, p, nk, use_kernel=use_kernel)

    f_dense_c = compiled_flops(dense_construction, stacked, perms, n_k)
    f_stream_c = compiled_flops(stream_construction, stacked, perms, n_k)
    f_dense_e2e = compiled_flops(
        gtg_shapley_batched, stacked, n_k, w_prev, utility, batched, key,
        **kw)
    # probed unchunked so both e2e programs are single-pass (XLA's
    # cost_analysis undercounts flops inside a lax.map/scan body)
    f_stream_e2e = compiled_flops(
        gtg_shapley_streaming, stacked, n_k, w_prev, utility, batched, key,
        sv_chunk=-1, **kw)

    def _j(x: float):   # NaN -> null in JSON (same convention as --grid)
        return None if x != x else x

    # peak bytes of resident prefix models (analytic: f32 leaves):
    # dense materialises all R*M models (+ the (R*M, M) weight matrix);
    # streaming at the off-TPU auto chunk keeps ONE walk's M models
    bytes_dense = n_perms * m * d_total * 4 + n_perms * m * m * 4
    bytes_stream_auto = m * d_total * 4
    tag = f"M{m}_R{n_perms}_D{d_total}"
    speedup = t_dense / max(t_stream, 1e-12)
    rows = [
        f"shapley_dense_{tag},{t_dense * 1e6:.0f},impl=batched",
        f"shapley_streaming_{tag},{t_stream * 1e6:.0f},"
        f"speedup_x{speedup:.2f}_"
        f"peak_model_bytes={bytes_stream_auto}_vs_dense_{bytes_dense}",
        f"shapley_streaming_unchunked_{tag},{t_unchunked * 1e6:.0f},"
        f"sv_chunk=-1",
        f"shapley_construction_flops,{f_dense_c:.0f},"
        f"streaming={f_stream_c:.0f}"
        f"_reduction_x{f_dense_c / f_stream_c:.1f}"
        if f_dense_c == f_dense_c and f_stream_c == f_stream_c and f_stream_c
        else "shapley_construction_flops,0,unavailable_on_this_backend",
    ]
    report = {
        "schema": "bench_shapley/v1",
        "backend": jax.default_backend(),
        "mode": "full" if full else "smoke",
        "config": {"m": m, "n_perms": n_perms, "d_total": d_total,
                   "n_val": n_val, "use_kernel": use_kernel},
        "latency_us": {
            "dense": t_dense * 1e6,
            "streaming": t_stream * 1e6,        # engines' default (auto)
            "streaming_unchunked": t_unchunked * 1e6,
        },
        "speedup_streaming_vs_dense": speedup,
        "compiled_flops": {
            "dense_construction": _j(f_dense_c),
            "streaming_construction": _j(f_stream_c),
            "construction_reduction":
                _j(f_dense_c / f_stream_c)
                if f_stream_c == f_stream_c and f_stream_c else None,
            "dense_e2e": _j(f_dense_e2e),
            "streaming_e2e": _j(f_stream_e2e),
        },
        "peak_model_bytes_estimate": {
            "dense": bytes_dense,
            "streaming_unchunked": n_perms * m * d_total * 4,
            "streaming_auto_off_tpu": bytes_stream_auto,
        },
    }
    _write_report(json_path, report, rows)
    return rows


def run_telemetry_bench(*, full: bool = False,
                        json_path: str | None = "BENCH_telemetry.json"
                        ) -> list[str]:
    """The `make telemetry-smoke` payload: telemetry overhead at the
    engine-bench shape — e2e greedyfed scan runs with telemetry off vs
    host-side (JSONL to disk) vs the in-scan live tap, min-of-reps on
    warm executables, into BENCH_telemetry.json.

    Acceptance: the host-side stream (the default observability mode,
    DESIGN.md §15) costs < 2% e2e — it only unrolls stacked outputs the
    result rebuild already fetched.  The live tap recompiles the scan
    with per-round `jax.debug.callback`s, so its overhead is reported as
    the diagnostic-mode price, not held to the 2% bar.  A segmented grid
    run with telemetry rides along to exercise (and schema-validate) the
    segment/heartbeat/checkpoint event path.
    """
    import os
    import tempfile

    from repro.grid import GridSpec, run_grid
    from repro.telemetry import Telemetry, validate_events

    base_kw = BASE if full else SMOKE
    rounds = 30 if full else 12
    reps = 5
    cfg = FLConfig(engine="scan", selector="greedyfed", rounds=rounds,
                   shapley_max_iters=(50 if full else 8), **base_kw)
    tag = f"N{cfg.n_clients}_M{cfg.m}_T{rounds}"

    tmp = tempfile.mkdtemp(prefix="telemetry_bench_")

    # warm both executables (the live tap compiles its own scan) so every
    # timed rep measures steady state, as a sweep would consume the engine
    run_federated(cfg)
    run_federated(cfg, telemetry=Telemetry(live_tap=True))

    # round-robin the three modes within each rep: sequential blocks let
    # slow box-load drift masquerade as (even negative) telemetry
    # overhead; interleaving exposes every mode to the same drift
    modes = {
        "off": lambda i: None,
        "host": lambda i: Telemetry(
            path=os.path.join(tmp, f"host{i}.jsonl")),
        "live": lambda i: Telemetry(
            path=os.path.join(tmp, f"live{i}.jsonl"), live_tap=True),
    }
    best = {name: float("inf") for name in modes}
    for i in range(reps):
        for name, make_tel in modes.items():
            tel = make_tel(i)
            t0 = time.perf_counter()
            run_federated(cfg, telemetry=tel)
            best[name] = min(best[name], time.perf_counter() - t0)
            if tel is not None:
                tel.close()
    t_off, t_host, t_live = best["off"], best["host"], best["live"]
    host_pct = (t_host - t_off) / t_off * 100
    live_pct = (t_live - t_off) / t_off * 100

    # the segmented-grid event path: segments, heartbeat, checkpoints,
    # per-cell unroll — then schema-validate the whole stream
    gcfg = dataclasses.replace(cfg, rounds=4)
    gspec = GridSpec.product(gcfg, selectors=["greedyfed", "fedavg"],
                             seeds=(0,))
    gpath = os.path.join(tmp, "grid.jsonl")
    gtel = Telemetry(path=gpath, heartbeat_every_s=1e9)
    run_grid(gspec, rounds_per_segment=2,
             checkpoint_dir=os.path.join(tmp, "ckpt"), telemetry=gtel)
    gtel.close()
    from repro.telemetry import read_events
    n_events = validate_events(read_events(gpath))

    rows = [
        f"telemetry_off_{tag},{t_off * 1e6:.0f},baseline",
        f"telemetry_host_{tag},{t_host * 1e6:.0f},"
        f"overhead_pct={host_pct:.2f}",
        f"telemetry_live_tap_{tag},{t_live * 1e6:.0f},"
        f"overhead_pct={live_pct:.2f}",
        f"telemetry_grid_events,{n_events},schema_validated",
    ]
    report = {
        "schema": "bench_telemetry/v1",
        "mode": "full" if full else "smoke",
        "config": {"n_clients": cfg.n_clients, "m": cfg.m,
                   "rounds": rounds, "engine": "scan",
                   "selector": "greedyfed", "reps": reps},
        "e2e_us": {"off": t_off * 1e6, "host": t_host * 1e6,
                   "live_tap": t_live * 1e6},
        "overhead_pct": {"host": host_pct, "live_tap": live_pct},
        "host_overhead_under_2pct": bool(host_pct < 2.0),
        "grid_stream": {"events": n_events, "validated": True},
    }
    _write_report(json_path, report, rows)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--full", action="store_true",
                    help="paper-scale shapley iteration budget")
    ap.add_argument("--smoke", action="store_true",
                    help="small CI-gate sizes (scripts/check.sh opt-in)")
    ap.add_argument("--grid", action="store_true",
                    help="grid-runner smoke (partitioned/segmented/"
                         "sharded) emitting BENCH_grid.json")
    ap.add_argument("--shapley", action="store_true",
                    help="dense-vs-streaming device GTG-Shapley smoke "
                         "emitting BENCH_shapley.json")
    ap.add_argument("--telemetry", action="store_true",
                    help="telemetry overhead bench (off vs host-side vs "
                         "live tap) emitting BENCH_telemetry.json")
    ap.add_argument("--json", default=None,
                    help="machine-readable report path ('' disables; "
                         "default BENCH_selection.json, BENCH_grid.json "
                         "with --grid, BENCH_shapley.json with --shapley, "
                         "or BENCH_telemetry.json with --telemetry)")
    args = ap.parse_args()
    if args.grid:
        json_path = ("BENCH_grid.json" if args.json is None
                     else (args.json or None))
        out_rows = run_grid_bench(full=args.full, json_path=json_path)
    elif args.shapley:
        json_path = ("BENCH_shapley.json" if args.json is None
                     else (args.json or None))
        out_rows = run_shapley_bench(full=args.full, json_path=json_path)
    elif args.telemetry:
        json_path = ("BENCH_telemetry.json" if args.json is None
                     else (args.json or None))
        out_rows = run_telemetry_bench(full=args.full, json_path=json_path)
    else:
        json_path = ("BENCH_selection.json" if args.json is None
                     else (args.json or None))
        out_rows = run(full=args.full, smoke=args.smoke,
                       json_path=json_path)
    for row in out_rows:
        print(row)

"""Round-engine benchmark: fused batched round vs the legacy per-client loop.

Two measurements (the engines are parity-exact, tests/test_engine.py):

  * round latency — time for ONE round's result to materialise (blocking).
    This is what every SV-driven strategy pays: GreedyFed/UCB/S-FedAvg
    consume the round's Shapley values before the next selection, so the
    round chain can never pipeline.  The legacy loop issues M+1 dispatches
    per round; the fused engine exactly one with donated params.
    (A pure-random selector never reads round outputs, letting the PJRT
    CPU runtime overlap the loop's independent client programs across
    rounds — a throughput artifact no paper workload can exploit.)

  * end-to-end greedyfed — steady-state seconds/round of full
    `run_federated` runs, (T_long - T_short)/(rounds difference), so
    setup + compile cancels.

Plus multi-seed amortisation (`run_federated_replicated`) and a
virtual-clock deadline sweep (time-derived stragglers, DESIGN.md §9).
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregation import (
    normalized_weights, tree_stack, weighted_average,
)
from repro.engine.round_engine import RoundEngine, RoundSpec
from repro.engine.schedule import ScheduleConfig
from repro.federated.client import ClientConfig, client_update
from repro.federated.server import (
    FLConfig, run_federated, run_federated_replicated, setup_run,
)

# acceptance config: M=10 of N=50 clients per round
BASE = dict(
    n_clients=50, m=10, n_train=2500, n_val=300, n_test=300,
    eval_every=1000,   # keep eval dispatches out of the round timing
    client=ClientConfig(epochs=3, batches_per_epoch=3, batch_size=32),
)
R_SHORT, R_LONG = 2, 10


def _timeit_chain(fn, params, reps=10) -> float:
    """Time `params = fn(params)` chained, as the server consumes it.

    Chaining keeps the measurement donation-safe on accelerators (the
    fused step donates its params buffer, so re-calling with the same
    pytree would fail there) and blocks each call on the previous round.
    """
    p = jax.block_until_ready(fn(params))  # compile + warm
    t0 = time.perf_counter()
    for _ in range(reps):
        p = fn(p)
    jax.block_until_ready(p)
    return (time.perf_counter() - t0) / reps


def _round_latency_rows() -> tuple[list[str], float]:
    cfg = FLConfig(**BASE)
    s = setup_run(cfg)
    sel = np.arange(cfg.m)
    epochs_k = np.full(cfg.m, cfg.client.epochs, np.int32)
    key = jax.random.key(1)

    def loop_round(params):
        # the legacy engine's round body, verbatim shape (M+1 dispatches)
        ckeys = jax.random.split(key, cfg.m + 1)
        ups = [client_update(s.model, cfg.client, params, s.xs[k], s.ys[k],
                             s.n_valid[k], jnp.asarray(int(epochs_k[i])),
                             jnp.asarray(s.sigma_k_all[k]), ckeys[i])
               for i, k in enumerate(sel)]
        stacked = tree_stack(ups)
        n_k = s.n_k_all[jnp.asarray(sel)]
        return weighted_average(stacked, normalized_weights(n_k))

    engine = RoundEngine(s.model, cfg.client, RoundSpec(), s.xs, s.ys,
                         s.n_valid, jnp.asarray(s.sigma_k_all),
                         s.x_val, s.y_val)

    t_loop = _timeit_chain(loop_round, s.params)
    # fresh copy: the fused step donates its params argument on accelerators
    t_fuse = _timeit_chain(
        lambda p: engine.step(p, sel, epochs_k, key).params,
        jax.tree.map(jnp.copy, s.params))
    return [
        f"round_latency_loop_N50_M10,{t_loop * 1e6:.0f},dispatches=11",
        f"round_latency_batched_N50_M10,{t_fuse * 1e6:.0f},"
        f"dispatches=1_speedup_x{t_loop / max(t_fuse, 1e-12):.2f}",
    ], t_fuse


def _per_round_e2e(cfg: FLConfig) -> tuple[float, int]:
    """Steady-state (seconds, dispatches) per round of full runs; the
    rounds=1 warmup plus the long-short difference cancels setup/compile."""
    run_federated(dataclasses.replace(cfg, rounds=1))
    short = run_federated(dataclasses.replace(cfg, rounds=R_SHORT))
    long = run_federated(dataclasses.replace(cfg, rounds=R_LONG))
    dt = (long.wall_time_s - short.wall_time_s) / (R_LONG - R_SHORT)
    ddisp = (long.dispatches - short.dispatches) // (R_LONG - R_SHORT)
    return dt, ddisp


def run(*, full: bool = False) -> list[str]:
    # shared-executable amortisation: the fused step is cached process-wide
    # on (model, client cfg, spec), so every later seed of a table cell
    # skips tracing+compilation entirely.  Must run FIRST (cold cache).
    rcfg0 = FLConfig(engine="batched", selector="fedavg", rounds=R_SHORT,
                     **BASE)
    cold = run_federated(rcfg0).wall_time_s
    warm = run_federated(dataclasses.replace(rcfg0, seed=1)).wall_time_s
    rows = [
        f"fused_run_cold_compile,{cold * 1e6:.0f},rounds={R_SHORT}",
        f"fused_run_cached_seed1,{warm * 1e6:.0f},"
        f"shared_executable_x{cold / max(warm, 1e-12):.2f}",
    ]

    lat_rows, t_fuse_round = _round_latency_rows()
    rows += lat_rows
    shapley_iters = 50 if full else 8

    cfg = dict(BASE, selector="greedyfed", shapley_max_iters=shapley_iters)
    t_loop, d_loop = _per_round_e2e(FLConfig(engine="loop", **cfg))
    t_fuse, d_fuse = _per_round_e2e(FLConfig(engine="batched", **cfg))
    rows.append(f"e2e_loop_greedyfed_N50_M10,{t_loop * 1e6:.0f},"
                f"dispatches_per_round={d_loop}")
    rows.append(f"e2e_batched_greedyfed_N50_M10,{t_fuse * 1e6:.0f},"
                f"dispatches_per_round={d_fuse}_"
                f"speedup_x{t_loop / max(t_fuse, 1e-12):.2f}")

    # multi-seed vmap: ONE dispatch advances S replicas.  On CPU the
    # batched while-loops undercut raw throughput (vs S solo fused rounds);
    # the dispatch-count reduction is the part that transfers to TPU.
    seeds = (0, 1, 2, 3) if full else (0, 1)
    rcfg = FLConfig(engine="batched", selector="fedavg", **BASE)
    run_federated_replicated(dataclasses.replace(rcfg, rounds=1), seeds)
    rep_s = run_federated_replicated(
        dataclasses.replace(rcfg, rounds=R_SHORT), seeds)
    rep_l = run_federated_replicated(
        dataclasses.replace(rcfg, rounds=R_LONG), seeds)
    t_rep = (rep_l[0].wall_time_s - rep_s[0].wall_time_s) / (R_LONG - R_SHORT)
    t_solo = t_fuse_round * len(seeds)
    rows.append(f"replicated_{len(seeds)}seeds_per_round,{t_rep * 1e6:.0f},"
                f"dispatches=1_for_{len(seeds)}_replicas_"
                f"solo_{len(seeds)}x={t_solo * 1e6:.0f}us")

    # deadline sweep: the scheduler turns tau into an accuracy/time knob
    for tau in (0.05, 0.5, 5.0):
        r = run_federated(dataclasses.replace(
            rcfg, rounds=R_LONG, eval_every=R_LONG,
            schedule=ScheduleConfig(deadline_s=tau, epoch_time_mean_s=0.1)))
        rows.append(f"deadline_tau{tau}s,{r.sim_time_s * 1e6:.0f},"
                    f"sim_time_acc={r.final_acc:.3f}")
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)

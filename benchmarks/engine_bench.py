"""Round/run-engine benchmark: loop vs batched vs whole-run scan.

Three execution tiers (parity-pinned by tests/test_engine.py):

  * loop    — the legacy per-client python loop: M+1 dispatches per round;
  * batched — `round_step`: ONE dispatch per round with donated params;
  * scan    — `run_scan`: the WHOLE T-round run (device-resident selection
              and valuation included) as one `lax.scan` dispatch.

Measurements:

  * round latency — time for ONE round's result to materialise (blocking).
    This is what every SV-driven strategy pays: GreedyFed/UCB/S-FedAvg
    consume the round's Shapley values before the next selection, so the
    round chain can never pipeline.  (A pure-random selector never reads
    round outputs, letting the PJRT CPU runtime overlap the loop's
    independent client programs across rounds — a throughput artifact no
    paper workload can exploit.)

  * end-to-end greedyfed — steady-state seconds/round of full
    `run_federated` runs, (T_long - T_short)/(rounds difference), so setup
    (and, for loop/batched, compile) cancels; the scan engine compiles one
    executable per T, so a small residual compile delta stays in its
    number — the dispatch counts are the load-bearing comparison.

Plus multi-seed amortisation (`run_federated_replicated`, per-round and
whole-run flavours) and a virtual-clock deadline sweep (DESIGN.md §9).

`run(json_path=...)` (or `make bench-smoke`) additionally writes
BENCH_selection.json — machine-readable dispatch counts and latencies so
the selection-path perf trajectory is tracked across PRs.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregation import (
    normalized_weights, tree_stack, weighted_average,
)
from repro.engine.round_engine import RoundEngine, RoundSpec
from repro.engine.schedule import ScheduleConfig
from repro.federated.client import ClientConfig, client_update
from repro.federated.server import (
    FLConfig, run_federated, run_federated_replicated, setup_run,
)

# acceptance config: M=10 of N=50 clients per round
BASE = dict(
    n_clients=50, m=10, n_train=2500, n_val=300, n_test=300,
    eval_every=1000,   # keep eval dispatches out of the round timing
    client=ClientConfig(epochs=3, batches_per_epoch=3, batch_size=32),
)
# CI-smoke config: same shape, small enough for scripts/check.sh
SMOKE = dict(
    n_clients=16, m=4, n_train=800, n_val=120, n_test=120,
    eval_every=1000,
    client=ClientConfig(epochs=2, batches_per_epoch=2, batch_size=32),
)
R_SHORT, R_LONG = 2, 10


def _timeit_chain(fn, params, reps=10) -> float:
    """Time `params = fn(params)` chained, as the server consumes it.

    Chaining keeps the measurement donation-safe on accelerators (the
    fused step donates its params buffer, so re-calling with the same
    pytree would fail there) and blocks each call on the previous round.
    """
    p = jax.block_until_ready(fn(params))  # compile + warm
    t0 = time.perf_counter()
    for _ in range(reps):
        p = fn(p)
    jax.block_until_ready(p)
    return (time.perf_counter() - t0) / reps


def _round_latency_rows(base: dict) -> tuple[list[str], dict, float]:
    cfg = FLConfig(**base)
    s = setup_run(cfg)
    sel = np.arange(cfg.m)
    epochs_k = np.full(cfg.m, cfg.client.epochs, np.int32)
    key = jax.random.key(1)
    tag = f"N{cfg.n_clients}_M{cfg.m}"

    def loop_round(params):
        # the legacy engine's round body, verbatim shape (M+1 dispatches)
        ckeys = jax.random.split(key, cfg.m + 1)
        ups = [client_update(s.model, cfg.client, params, s.xs[k], s.ys[k],
                             s.n_valid[k], jnp.asarray(int(epochs_k[i])),
                             jnp.asarray(s.sigma_k_all[k]), ckeys[i])
               for i, k in enumerate(sel)]
        stacked = tree_stack(ups)
        n_k = s.n_k_all[jnp.asarray(sel)]
        return weighted_average(stacked, normalized_weights(n_k))

    engine = RoundEngine(s.model, cfg.client, RoundSpec(), s.xs, s.ys,
                         s.n_valid, jnp.asarray(s.sigma_k_all),
                         s.x_val, s.y_val)

    t_loop = _timeit_chain(loop_round, s.params)
    # fresh copy: the fused step donates its params argument on accelerators
    t_fuse = _timeit_chain(
        lambda p: engine.step(p, sel, epochs_k, key).params,
        jax.tree.map(jnp.copy, s.params))
    rows = [
        f"round_latency_loop_{tag},{t_loop * 1e6:.0f},"
        f"dispatches={cfg.m + 1}",
        f"round_latency_batched_{tag},{t_fuse * 1e6:.0f},"
        f"dispatches=1_speedup_x{t_loop / max(t_fuse, 1e-12):.2f}",
    ]
    stats = {"loop": t_loop * 1e6, "batched": t_fuse * 1e6}
    return rows, stats, t_fuse


def _per_round_e2e(cfg: FLConfig, r_long: int) -> tuple[float, int, int]:
    """Steady-state (seconds/round, dispatches/round, total dispatches of
    the long run); the rounds=1 warmup plus the long-short difference
    cancels setup (and loop/batched compile)."""
    run_federated(dataclasses.replace(cfg, rounds=1))
    short = run_federated(dataclasses.replace(cfg, rounds=R_SHORT))
    long = run_federated(dataclasses.replace(cfg, rounds=r_long))
    dt = (long.wall_time_s - short.wall_time_s) / (r_long - R_SHORT)
    ddisp = (long.dispatches - short.dispatches) // (r_long - R_SHORT)
    return dt, ddisp, long.dispatches


def run(*, full: bool = False, smoke: bool = False,
        json_path: str | None = None) -> list[str]:
    base = SMOKE if smoke else BASE
    r_long = 6 if smoke else R_LONG
    tag = f"N{base['n_clients']}_M{base['m']}"
    report: dict = {
        "schema": "bench_selection/v1",
        "backend": jax.default_backend(),
        "mode": "smoke" if smoke else ("full" if full else "quick"),
        "config": {"n_clients": base["n_clients"], "m": base["m"],
                   "rounds_short": R_SHORT, "rounds_long": r_long},
    }

    # shared-executable amortisation: the fused step is cached process-wide
    # on (model, client cfg, spec), so every later seed of a table cell
    # skips tracing+compilation entirely.  Must run FIRST (cold cache).
    rcfg0 = FLConfig(engine="batched", selector="fedavg", rounds=R_SHORT,
                     **base)
    cold = run_federated(rcfg0).wall_time_s
    warm = run_federated(dataclasses.replace(rcfg0, seed=1)).wall_time_s
    rows = [
        f"fused_run_cold_compile,{cold * 1e6:.0f},rounds={R_SHORT}",
        f"fused_run_cached_seed1,{warm * 1e6:.0f},"
        f"shared_executable_x{cold / max(warm, 1e-12):.2f}",
    ]

    lat_rows, lat_stats, t_fuse_round = _round_latency_rows(base)
    rows += lat_rows
    report["round_latency_us"] = lat_stats
    shapley_iters = 50 if full else 8

    cfg = dict(base, selector="greedyfed", shapley_max_iters=shapley_iters)
    t_loop, d_loop, _ = _per_round_e2e(FLConfig(engine="loop", **cfg), r_long)
    t_fuse, d_fuse, _ = _per_round_e2e(FLConfig(engine="batched", **cfg),
                                       r_long)
    t_scan, _, scan_total = _per_round_e2e(FLConfig(engine="scan", **cfg),
                                           r_long)
    rows.append(f"e2e_loop_greedyfed_{tag},{t_loop * 1e6:.0f},"
                f"dispatches_per_round={d_loop}")
    rows.append(f"e2e_batched_greedyfed_{tag},{t_fuse * 1e6:.0f},"
                f"dispatches_per_round={d_fuse}_"
                f"speedup_x{t_loop / max(t_fuse, 1e-12):.2f}")
    rows.append(f"e2e_scan_greedyfed_{tag},{t_scan * 1e6:.0f},"
                f"dispatches_total={scan_total}_"
                f"speedup_x{t_loop / max(t_scan, 1e-12):.2f}")
    report["e2e_greedyfed"] = {
        "loop": {"us_per_round": t_loop * 1e6,
                 "dispatches_per_round": d_loop},
        "batched": {"us_per_round": t_fuse * 1e6,
                    "dispatches_per_round": d_fuse},
        "scan": {"us_per_round": t_scan * 1e6,
                 "dispatches_per_round": 0,       # amortised: 1 per run
                 "dispatches_total": scan_total},
    }
    report["speedup"] = {
        "batched_vs_loop_round_latency":
            lat_stats["loop"] / max(lat_stats["batched"], 1e-9),
        "batched_vs_loop_e2e": t_loop / max(t_fuse, 1e-12),
        "scan_vs_loop_e2e": t_loop / max(t_scan, 1e-12),
        "scan_vs_batched_e2e": t_fuse / max(t_scan, 1e-12),
    }

    # multi-seed vmap: ONE dispatch advances S replicas (per-round flavour)
    # or S whole runs (scan flavour).  On CPU the batched while-loops
    # undercut raw throughput (vs S solo fused rounds); the dispatch-count
    # reduction is the part that transfers to TPU.
    seeds = (0, 1, 2, 3) if full else (0, 1)
    rcfg = FLConfig(engine="batched", selector="fedavg", **base)
    run_federated_replicated(dataclasses.replace(rcfg, rounds=1), seeds)
    rep_s = run_federated_replicated(
        dataclasses.replace(rcfg, rounds=R_SHORT), seeds)
    rep_l = run_federated_replicated(
        dataclasses.replace(rcfg, rounds=r_long), seeds)
    t_rep = (rep_l[0].wall_time_s - rep_s[0].wall_time_s) / (r_long - R_SHORT)
    t_solo = t_fuse_round * len(seeds)
    rows.append(f"replicated_{len(seeds)}seeds_per_round,{t_rep * 1e6:.0f},"
                f"dispatches=1_for_{len(seeds)}_replicas_"
                f"solo_{len(seeds)}x={t_solo * 1e6:.0f}us")

    scfg = FLConfig(engine="scan", selector="fedavg", **base)
    grid = run_federated_replicated(
        dataclasses.replace(scfg, rounds=r_long), seeds)
    rows.append(f"replicated_scan_{len(seeds)}seeds_whole_run,"
                f"{grid[0].wall_time_s * 1e6:.0f},"
                f"dispatches={grid[0].dispatches}_for_{len(seeds)}_full_runs")
    report["replicated"] = {
        "seeds": len(seeds),
        "per_round_us": t_rep * 1e6,
        "scan_whole_run_us": grid[0].wall_time_s * 1e6,
        "scan_whole_run_dispatches": grid[0].dispatches,
    }

    # deadline sweep: the scheduler turns tau into an accuracy/time knob
    for tau in (0.05, 0.5, 5.0):
        r = run_federated(dataclasses.replace(
            rcfg, rounds=r_long, eval_every=r_long,
            schedule=ScheduleConfig(deadline_s=tau, epoch_time_mean_s=0.1)))
        rows.append(f"deadline_tau{tau}s,{r.sim_time_s * 1e6:.0f},"
                    f"sim_time_acc={r.final_acc:.3f}")

    if json_path:
        with open(json_path, "w") as f:
            json.dump(report, f, indent=2, sort_keys=True)
            f.write("\n")
        rows.append(f"json_report,0,{json_path}")
    return rows


def run_grid_bench(*, full: bool = False,
                   json_path: str | None = "BENCH_grid.json") -> list[str]:
    """The `make grid-smoke` payload: a 2-partition (greedyfed+fedavg),
    2-segment, 4-replica grid through `repro.grid.run_grid`, sharded over
    the replica mesh (4 of the forced-host 8 devices in CI), emitting
    BENCH_grid.json — per-partition dispatch counts and compiled-flops
    evidence that the non-SV partition no longer traces GTG-Shapley,
    segment latency, bytes resident per partition/device, and a
    mixed-`eval_every` grid row (DESIGN.md §13: per-cell cadences, still
    one dispatch per partition per segment).
    """
    import jax

    from repro.grid import GridCell, GridSpec, run_grid

    base_kw = BASE if full else SMOKE
    rounds, k = (8, 4) if full else (4, 2)
    cfg = FLConfig(selector="greedyfed", engine="scan",
                   shapley_max_iters=(50 if full else 8), rounds=rounds,
                   **base_kw)
    gspec = GridSpec.product(cfg, selectors=["greedyfed", "fedavg"],
                             seeds=(0, 1))

    cold = run_grid(gspec, rounds_per_segment=k, compile_stats=True)
    warm = run_grid(gspec, rounds_per_segment=k)   # executables cached
    n_segments = warm.n_segments
    seg_us = warm.wall_time_s / max(
        sum(p.dispatches for p in warm.partitions), 1) * 1e6

    n_dev = len(jax.devices())
    rows, parts = [], []
    for p in cold.partitions:
        rows.append(
            f"grid_partition_{p.label},{p.dispatches},needs_sv={p.needs_sv}"
            f"_evals={p.shapley_evals}_flops={p.flops_per_dispatch:.0f}")
        parts.append({
            "label": p.label, "cells": list(p.cell_indices),
            "needs_sv": p.needs_sv,
            "uses_local_losses": p.uses_local_losses,
            "n_strategies": p.n_strategies,
            "dispatches": p.dispatches,
            "shapley_evals": p.shapley_evals,
            "bytes_resident": p.bytes_resident,
            "flops_per_dispatch": None
            if p.flops_per_dispatch != p.flops_per_dispatch
            else p.flops_per_dispatch,
        })
    rows.append(f"grid_segment_latency,{seg_us:.0f},"
                f"segments={n_segments}_cells={len(gspec.cells)}")
    bytes_total = sum(p.bytes_resident for p in cold.partitions)
    shard_dev = min(n_dev, 2)   # 2 replicas per partition
    rows.append(f"grid_bytes_resident,{bytes_total},"
                f"per_device={bytes_total // max(shard_dev, 1)}"
                f"_devices={n_dev}")

    # mixed per-cell eval cadences (DESIGN.md §13): one partition, one
    # dispatch per segment, every replica on its own eval curve
    mixed = GridSpec(cfg, (
        GridCell("fedavg", 0),                                 # base cadence
        GridCell("fedavg", 0, overrides={"eval_every": 1}),    # every round
        GridCell("fedavg", 1, overrides={"eval_every": rounds + 1})))
    mg = run_grid(mixed, rounds_per_segment=k)
    evals_per_cell = [len(r.test_acc) for r in mg.results]
    mg_dispatches = sum(p.dispatches for p in mg.partitions)
    rows.append(f"grid_mixed_eval_cadence,{mg_dispatches},"
                f"cells={len(mixed.cells)}_evals_per_cell="
                f"{'/'.join(map(str, evals_per_cell))}")

    sv = next(p for p in cold.partitions if p.needs_sv)
    plain = next(p for p in cold.partitions if not p.needs_sv)
    report = {
        "schema": "bench_grid/v1",
        "backend": jax.default_backend(),
        "devices": n_dev,
        "grid": {"cells": len(gspec.cells),
                 "selectors": ["greedyfed", "fedavg"], "seeds": [0, 1],
                 "rounds": rounds, "rounds_per_segment": k,
                 "n_segments": n_segments},
        "partitions": parts,
        "mixed_eval_cadence": {
            "cells": len(mixed.cells),
            "eval_every": [c.eval_every for c in mixed.cell_configs()],
            "evals_per_cell": evals_per_cell,
            "dispatches": mg_dispatches,
            "n_segments": mg.n_segments,
        },
        "segment_latency_us": seg_us,
        "bytes_resident_total": bytes_total,
        "bytes_resident_per_device": bytes_total // max(shard_dev, 1),
        "sv_partition_skipped_in_plain": {
            "plain_partition_shapley_evals": plain.shapley_evals,
            "flops_ratio_sv_over_plain": None
            if sv.flops_per_dispatch != sv.flops_per_dispatch
            or plain.flops_per_dispatch != plain.flops_per_dispatch
            else sv.flops_per_dispatch / plain.flops_per_dispatch,
        },
    }
    if json_path:
        with open(json_path, "w") as f:
            json.dump(report, f, indent=2, sort_keys=True)
            f.write("\n")
        rows.append(f"json_report,0,{json_path}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--full", action="store_true",
                    help="paper-scale shapley iteration budget")
    ap.add_argument("--smoke", action="store_true",
                    help="small CI-gate sizes (scripts/check.sh opt-in)")
    ap.add_argument("--grid", action="store_true",
                    help="grid-runner smoke (partitioned/segmented/"
                         "sharded) emitting BENCH_grid.json")
    ap.add_argument("--json", default=None,
                    help="machine-readable report path ('' disables; "
                         "default BENCH_selection.json, or BENCH_grid.json "
                         "with --grid)")
    args = ap.parse_args()
    if args.grid:
        json_path = ("BENCH_grid.json" if args.json is None
                     else (args.json or None))
        out_rows = run_grid_bench(full=args.full, json_path=json_path)
    else:
        json_path = ("BENCH_selection.json" if args.json is None
                     else (args.json or None))
        out_rows = run(full=args.full, smoke=args.smoke,
                       json_path=json_path)
    for row in out_rows:
        print(row)

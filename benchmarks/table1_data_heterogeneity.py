"""Paper Table I: accuracy under Dirichlet(alpha) data heterogeneity."""
from benchmarks.fl_common import print_table, sweep

VALUES = [1e-4, 0.1, 100.0]


def run(*, full=False, seeds=(0, 1), dataset="mnist", engine="loop"):
    rows = sweep("dirichlet_alpha", VALUES, dataset=dataset, seeds=seeds,
                 full=full, engine=engine)
    print_table("Table I — data heterogeneity (alpha)", rows, VALUES)
    return rows


if __name__ == "__main__":
    run()
